"""ESTMM — expert-specific transposed matmul (standalone Pallas TPU kernel).

dW[e] = sum_{rows i in e} x1[i]^T x2[i] (paper Fig. 4(d)). Production uses
the fused ESFK kernel (which adds the ESS output for free); this standalone
version exists for the unfused ablation (paper Fig. 12) and kernel tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common import pallas_interpret_default, tpu_compiler_params


def _estmm_kernel(block_expert, x1_ref, x2_ref, o_ref, acc_ref):
    m = pl.program_id(2)
    nm = pl.num_programs(2)
    cur = block_expert[m]
    prev = jnp.where(m == 0, -1, block_expert[jnp.maximum(m - 1, 0)])
    nxt = jnp.where(m == nm - 1, -1, block_expert[jnp.minimum(m + 1, nm - 1)])

    @pl.when(cur != prev)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x1_ref[...],
        x2_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(cur != nxt)
    def _done():
        o_ref[...] = acc_ref[...][None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "b1", "b2", "interpret"))
def estmm_pallas(
    x1: jax.Array,
    x2: jax.Array,
    block_expert: jax.Array,
    counts: jax.Array,
    *,
    bm: int = 128,
    b1: int = 128,
    b2: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """(Np, D1), (Np, D2) sorted rows -> (E, D1, D2) grads (f32)."""
    if interpret is None:
        interpret = pallas_interpret_default()
    np_rows, d1 = x1.shape
    _, d2 = x2.shape
    e = counts.shape[0]
    bm = min(bm, np_rows)
    b1 = min(b1, d1)
    b2 = min(b2, d2)
    assert np_rows % bm == 0 and d1 % b1 == 0 and d2 % b2 == 0
    assert block_expert.shape[0] * bm == np_rows
    grid = (d1 // b1, d2 // b2, np_rows // bm)

    out = pl.pallas_call(
        _estmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, b1), lambda i, j, m, be: (m, i)),
                pl.BlockSpec((bm, b2), lambda i, j, m, be: (m, j)),
            ],
            out_specs=pl.BlockSpec(
                (1, b1, b2), lambda i, j, m, be: (be[m], i, j)
            ),
            scratch_shapes=[pltpu.VMEM((b1, b2), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((e, d1, d2), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        cost_estimate=pl.CostEstimate(
            flops=2 * np_rows * d1 * d2,
            bytes_accessed=(
                (d2 // b2) * x1.size * x1.dtype.itemsize
                + (d1 // b1) * x2.size * x2.dtype.itemsize
                + e * d1 * d2 * 4
            ),
            transcendentals=0,
        ),
        interpret=interpret,
    )(block_expert, x1, x2)
    return jnp.where((counts > 0)[:, None, None], out, 0.0)
