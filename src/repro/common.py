"""Small shared utilities used across the framework."""
from __future__ import annotations

import functools
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


#: Activation table shared by the espec layer and the fused-FFN kernels
#: (kernels must not import core.espec — it imports kernels.ops).
ACTIVATIONS: dict = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


@functools.lru_cache(None)
def pallas_interpret_default() -> bool:
    """Pallas kernels run in interpret mode everywhere except real TPU."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return not on_tpu()


def tpu_compiler_params(**kwargs):
    """Mosaic compiler params across jax versions: the class is
    ``pltpu.CompilerParams`` on 2025-era jax but ``TPUCompilerParams`` on
    the 0.4.x line this toolchain pins."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def tree_bytes(tree: Any) -> int:
    """Total bytes of all arrays / ShapeDtypeStructs in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_params(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) for l in leaves if hasattr(l, "shape"))


def cast_tree(tree: Any, dtype) -> Any:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )
