"""Deterministic, resumable, host-sharded token data pipeline.

Every batch is a pure function of (seed, step, host_shard): restart at step
N reproduces exactly the stream a continuous run would have seen — the
property checkpoint-restart fault tolerance depends on. Sources:

  * synthetic — order-k Markov token stream (counter-based RNG; no state).
    Gives a learnable distribution so convergence examples show loss
    dropping below the unigram entropy floor.
  * memmap — int32 token file, strided windows, deterministic shuffle of
    window order by step hash.

A small background-thread prefetcher overlaps host batch assembly with
device compute, and supports *unequal* per-host batch shares so the
heterogeneous-aware planner (core.hetero, paper Eq. 1) can re-split load
at runtime — shares are a constructor argument and can be updated between
steps.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    kind: str = "synthetic"       # synthetic | memmap
    seed: int = 0
    path: Optional[str] = None    # memmap token file
    markov_order: int = 1


def _philox(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.Generator(
        np.random.Philox(key=np.uint64(seed), counter=[step, shard, 0, 0])
    )


class TokenSource:
    """Deterministic batch source; indexable by (step, shard)."""

    def __init__(self, cfg: DataConfig, num_shards: int = 1, shard: int = 0,
                 shares: Optional[Sequence[int]] = None):
        self.cfg = cfg
        self.num_shards = num_shards
        self.shard = shard
        self.set_shares(shares)
        if cfg.kind == "memmap":
            assert cfg.path, "memmap source needs a path"
            self._tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")
        elif cfg.kind == "synthetic":
            rng = _philox(cfg.seed, 0, 2**31 - 1)
            v = cfg.vocab_size
            # Markov chain over K token *classes* (token % K) so the table
            # stays small for large vocabs; within-class choice is uniform.
            self._k = min(v, 512)
            logits = rng.normal(size=(self._k, self._k)).astype(np.float32) * 2.0
            trans = np.exp(logits) / np.exp(logits).sum(1, keepdims=True)
            self._cum = np.cumsum(trans, axis=1)
        else:
            raise ValueError(cfg.kind)

    def set_shares(self, shares: Optional[Sequence[int]]) -> None:
        """Per-shard batch shares (heterogeneous splits). None = uniform."""
        if shares is None:
            assert self.cfg.global_batch % self.num_shards == 0
            shares = [self.cfg.global_batch // self.num_shards] * self.num_shards
        assert sum(shares) == self.cfg.global_batch, shares
        self._shares = list(shares)
        self._offsets = np.concatenate([[0], np.cumsum(shares)])

    @property
    def local_batch(self) -> int:
        return self._shares[self.shard]

    def batch(self, step: int) -> dict:
        """Host-local {tokens, labels, loss_mask} for this shard at step."""
        n = self._shares[self.shard]
        s = self.cfg.seq_len
        if self.cfg.kind == "synthetic":
            rng = _philox(self.cfg.seed, step, self.shard)
            v, k = self.cfg.vocab_size, self._k
            toks = np.empty((n, s + 1), np.int32)
            toks[:, 0] = rng.integers(0, v, size=n)
            u = rng.random(size=(n, s)).astype(np.float32)
            blocks = rng.integers(0, max(v // k, 1), size=(n, s)).astype(np.int32)
            for t in range(s):
                cls = (self._cum[toks[:, t] % k] < u[:, t:t + 1]).sum(axis=1)
                toks[:, t + 1] = np.minimum(cls + blocks[:, t] * k, v - 1)
        else:
            total_windows = (len(self._tokens) - 1) // s
            rng = _philox(self.cfg.seed, step, 0)
            order = rng.permutation(total_windows)
            base = (step * self.cfg.global_batch) % total_windows
            idx = order[(base + self._offsets[self.shard]
                         + np.arange(n)) % total_windows]
            toks = np.stack(
                [self._tokens[i * s:i * s + s + 1] for i in idx]
            ).astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "loss_mask": np.ones((n, s), np.float32),
        }


class Prefetcher:
    """Background-thread prefetch queue over a TokenSource."""

    def __init__(self, source: TokenSource, start_step: int = 0, depth: int = 2):
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
