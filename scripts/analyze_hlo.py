#!/usr/bin/env python
"""Perf-analysis over a dry-run HLO dump (.hlo.gz from dryrun --save-hlo):
per-shape collective breakdown, biggest tensors, duplicate-op (remat) count.

  python scripts/analyze_hlo.py dump.hlo.gz [--top 20]
"""
import argparse
import collections
import gzip
import re

DT = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1,
      "s8": 1, "u8": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2, "u16": 2}
CRE = re.compile(
    r"= ([a-z0-9]+)\[([\d,]*)\][^=]*? "
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
SRE = re.compile(r"([a-z0-9]+)\[([\d,]+)\]")


def nbytes(dt, dims):
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DT.get(dt, 0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()
    opener = gzip.open if args.path.endswith(".gz") else open
    txt = opener(args.path, "rt").read()

    print("== collectives by shape ==")
    agg, cnt = collections.Counter(), collections.Counter()
    for line in txt.splitlines():
        m = CRE.search(line)
        if not m:
            continue
        dt, dims, kind = m.groups()
        key = f"{kind} {dt}[{dims}]"
        agg[key] += nbytes(dt, dims)
        cnt[key] += 1
    for k, b in agg.most_common(args.top):
        print(f"{b / 1e9:9.3f} GB x{cnt[k]:4d}  {k}")

    print("\n== largest tensor shapes (mention counts) ==")
    sizes = collections.Counter()
    for m in SRE.finditer(txt):
        b = nbytes(m.group(1), m.group(2))
        if b > 100e6:
            sizes[f"{m.group(1)}[{m.group(2)}]"] += 1
    for k, c in sizes.most_common(args.top):
        dt = k.split("[")[0]
        print(f"{nbytes(dt, k[k.index('[') + 1:-1]) / 1e9:9.2f} GB "
              f"x{c:5d}  {k}")

    print("\n== op-kind counts (fusion/remat smell) ==")
    kinds = collections.Counter(
        m.group(1) for m in re.finditer(r"= \S+ ([a-z\-]+)\(", txt)
    )
    for k, c in kinds.most_common(15):
        print(f"{c:7d}  {k}")


if __name__ == "__main__":
    main()
