#!/usr/bin/env bash
# CI entry point: deps -> tier-1 tests (CPU, Pallas interpret) -> benchmark
# smoke -> docs-check. Mirrors what `make test/bench/docs-check` run locally.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -r requirements.txt

# Hygiene: compiled bytecode must never be tracked (it churns every commit
# and is machine-specific).
if git ls-files '*.pyc' '**/__pycache__/*' | grep -q .; then
    echo "ERROR: tracked bytecode files:" >&2
    git ls-files '*.pyc' '**/__pycache__/*' >&2
    exit 1
fi

# Tier-1 on CPU; Pallas kernels run in interpret mode off-TPU (this is the
# default in repro.common.pallas_interpret_default, forced here for clarity).
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export REPRO_PALLAS_INTERPRET=1
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Two test tiers (tests/conftest.py markers): tier-1 fast in-process tests
# first for quick failure, then the multihost tier (subprocess fake-device
# meshes: hierarchical dispatch parity, SPMD hetero execution, elastic CLI).
make test-tier1
make test-multihost

# Tier-2 chaos scenarios (DESIGN.md §9): deterministic fault plans through
# the real drivers — checkpoint-fallback bit-exactness, serving
# retry/re-jit stream parity, elastic shrink on device dropout.
make chaos

# Observability artifact validation (DESIGN.md §12): real train + serve
# runs with metrics/tracing/event-log on; grammar- and invariant-checked.
make obs-check

# Benchmark smoke: every paper-table module must at least run its quick grid
# (JAX_PLATFORMS=cpu via the Makefile) and emit BENCH_kernels.json +
# BENCH_hetero.json + BENCH_serve.json + BENCH_quant.json (the hetero suite
# runs the Eq. 1/2 uneven splits for real and asserts proportional <= uniform
# under simulated skew; the serve suite runs the mixed-length workload
# through the dense and paged drivers and asserts paged uses less peak KV
# cache with no tokens/s regression, then the high-duplicate prefix
# workload and asserts prefix-cached TTFT < uncached at a real hit-rate,
# then the speculative suite on a batch-1 repetitive workload and asserts
# spec-on decode tokens/s > 1.5x spec-off with token-identical output;
# the quant suite asserts int8 fused-FFN
# bytes < bf16, the crossover shift, and the equal-HBM paged-KV admission
# gain), so the harness and the machine-readable perf trajectory can't
# bit-rot.
make bench

# Validate the JSON files against the README-documented schema and pin the
# executed heterogeneous + paged-vs-dense serving comparison rows.
make bench-check

make docs-check
