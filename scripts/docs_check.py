#!/usr/bin/env python
"""Documentation consistency checks (`make docs-check`).

1. **Citation resolution** — every ``DESIGN.md §N`` citation anywhere under
   ``src/`` must resolve to a ``## §N`` heading in DESIGN.md (dangling
   section numbers fail).
2. **Docstring audit** — every public module, class, and top-level function
   in ``src/repro/parallel/``, ``src/repro/runtime/``, ``src/repro/quant/``,
   ``src/repro/launch/`` and ``src/repro/checkpoint/`` must carry a
   docstring; these are the layers
   whose contracts the paper sections / DESIGN §§ define, so an
   undocumented public entry point is a review failure, not a style nit.
"""
from __future__ import annotations

import ast
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
AUDITED_DIRS = ("src/repro/parallel", "src/repro/runtime", "src/repro/quant",
                "src/repro/launch", "src/repro/checkpoint", "src/repro/obs")


def check_citations() -> list[str]:
    with open(os.path.join(ROOT, "DESIGN.md")) as fh:
        headings = set(re.findall(r"^## §(\d+)\b", fh.read(), re.M))
    errors = []
    for dirpath, _, files in os.walk(os.path.join(ROOT, "src")):
        for fname in files:
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            with open(path) as fh:
                text = fh.read()
            for num in set(re.findall(r"DESIGN\.md §(\d+)", text)):
                if num not in headings:
                    rel = os.path.relpath(path, ROOT)
                    errors.append(f"dangling citation DESIGN.md §{num} "
                                  f"in {rel}")
    return errors


def check_docstrings() -> list[str]:
    errors = []
    for base in AUDITED_DIRS:
        for dirpath, _, files in os.walk(os.path.join(ROOT, base)):
            for fname in files:
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, ROOT)
                with open(path) as fh:
                    tree = ast.parse(fh.read(), filename=rel)
                if not ast.get_docstring(tree):
                    errors.append(f"{rel}: missing module docstring")
                for node in tree.body:
                    if not isinstance(
                        node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)
                    ):
                        continue
                    if node.name.startswith("_"):
                        continue
                    if not ast.get_docstring(node):
                        errors.append(
                            f"{rel}:{node.lineno}: public "
                            f"{type(node).__name__.replace('Def', '').lower()}"
                            f" '{node.name}' has no docstring"
                        )
    return errors


def main() -> int:
    errors = check_citations() + check_docstrings()
    if errors:
        for e in errors:
            print(f"docs-check: {e}", file=sys.stderr)
        return 1
    print("docs-check: all DESIGN.md citations resolve; "
          f"{' + '.join(AUDITED_DIRS)} public APIs documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
