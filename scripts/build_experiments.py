#!/usr/bin/env python
"""Assemble the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
cached cell JSONs. Regenerates content between AUTOGEN markers."""
import glob
import json
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "experiments", "dryrun")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")

ARCH_ORDER = [
    "qwen3_moe_30b_a3b", "mixtral_8x7b", "jamba_1_5_large_398b",
    "phi3_medium_14b", "starcoder2_15b", "gemma3_12b", "gemma_2b",
    "musicgen_large", "xlstm_350m", "paligemma_3b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(mesh):
    cells = {}
    for path in glob.glob(os.path.join(OUT, f"*__{mesh}.json")):
        base = os.path.basename(path)[: -len(f"__{mesh}.json")]
        arch, shape = base.rsplit("__", 1)
        with open(path) as f:
            cells[(arch, shape)] = json.load(f)
    return cells


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 0.01:
        return f"{x:.3f}"
    return f"{x:.2e}"


def dryrun_table(single, multi):
    lines = [
        "| arch | shape | mode | mesh 16x16 | peak GB/dev | mesh 2x16x16 |",
        "|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            s = single.get((arch, shape))
            m = multi.get((arch, shape))
            if s is None and m is None:
                continue
            if s and s.get("status") == "skipped":
                lines.append(
                    f"| {arch} | {shape} | - | SKIP ({s['reason'][:40]}) | - | SKIP |")
                continue

            def cellstat(c):
                if c is None:
                    return "pending"
                if c.get("status") != "ok":
                    return c.get("status", "?").upper()
                return "PASS"

            peak = "-"
            mode = "-"
            if s and s.get("status") == "ok":
                peak = f"{s['memory']['peak_per_device'] / 1e9:.1f}"
                mode = s.get("mode", "-")
            lines.append(
                f"| {arch} | {shape} | {mode} | {cellstat(s)} | {peak} "
                f"| {cellstat(m)} |"
            )
    return "\n".join(lines)


def roofline_table(single):
    lines = [
        "| arch | shape | t_comp (s) | t_mem kern (s) | t_coll (s) | dominant "
        "| MODEL_FLOPs/HLO | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            c = single.get((arch, shape))
            if c is None or c.get("status") != "ok":
                continue
            r = c["roofline"]
            frac = r.get("useful_flops_fraction")
            note = ""
            tk = r.get("t_memory_kernel_s", r["t_memory_s"])
            dom_t = max(r["t_compute_s"], tk, r["t_collective_s"])
            rf = r["t_compute_s"] / dom_t if dom_t else 0
            note = f"roofline frac {rf:.2f}"
            lines.append(
                f"| {arch} | {shape} | {fmt_s(r['t_compute_s'])} "
                f"| {fmt_s(tk)} | {fmt_s(r['t_collective_s'])} "
                f"| {r['dominant']} | {frac:.2f} | {note} |"
            )
    return "\n".join(lines)


def collective_summary(single):
    lines = [
        "| arch | shape | AG GB | AR GB | RS GB | A2A GB | CP GB |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            c = single.get((arch, shape))
            if c is None or c.get("status") != "ok":
                continue
            co = c.get("collectives", {})
            g = lambda k: co.get(k, {}).get("bytes", 0) / 1e9
            lines.append(
                f"| {arch} | {shape} | {g('all-gather'):.1f} "
                f"| {g('all-reduce'):.1f} | {g('reduce-scatter'):.1f} "
                f"| {g('all-to-all'):.2f} | {g('collective-permute'):.2f} |"
            )
    return "\n".join(lines)


def inject(text, marker, content):
    start = f"<!-- AUTOGEN:{marker} -->"
    end = f"<!-- /AUTOGEN:{marker} -->"
    block = f"{start}\n{content}\n{end}"
    if start in text:
        return re.sub(
            re.escape(start) + r".*?" + re.escape(end),
            lambda _: block, text, flags=re.S,
        )
    return text + "\n" + block + "\n"


def main():
    single = load_cells("single")
    multi = load_cells("multi")
    if not os.path.exists(EXP):
        text = "# EXPERIMENTS\n"
    else:
        with open(EXP) as f:
            text = f.read()
    text = inject(text, "dryrun", dryrun_table(single, multi))
    text = inject(text, "roofline", roofline_table(single))
    text = inject(text, "collectives", collective_summary(single))
    with open(EXP, "w") as f:
        f.write(text)
    n_ok = sum(1 for c in single.values() if c.get("status") == "ok")
    n_skip = sum(1 for c in single.values() if c.get("status") == "skipped")
    n_bad = sum(1 for c in single.values()
                if c.get("status") in ("error", "timeout"))
    print(f"single-pod: {n_ok} ok, {n_skip} skipped, {n_bad} failed; "
          f"multi-pod: {sum(1 for c in multi.values() if c.get('status') == 'ok')} ok")


if __name__ == "__main__":
    main()
