#!/usr/bin/env python
"""Observability artifact validator (`make obs-check`, DESIGN.md §12).

Drives the real train and paged-serve drivers end-to-end with every
pillar enabled (seeded, tiny configs), then validates the artifacts the
operator would scrape or load — not merely that the runs survived:

  prometheus   the text dump parses under the exposition-format grammar
               (# HELP/# TYPE headers, `name{labels} value` series,
               histogram `_bucket/_sum/_count` triples), and the router
               invariant holds per phase:
               sum(expert_tokens) == top_k * routed_tokens
  trace        the Chrome trace JSON loads, every event carries the
               required keys (name/ph/pid/tid/ts, dur for "X"), and the
               span union covers >= 95% of the traced wall window
  events       the JSONL event log parses line-by-line and every record
               carries a monotonic-clock stamp and a kind

Prints one PASS line per artifact; exits non-zero on the first failure.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import subprocess
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

SERIES_RE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*'            # metric name
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"'     # first label
    r'(,[a-zA-Z_][a-zA-Z0-9_]*="[^"]*")*\})?'
    r' [0-9eE.+-]+(\.[0-9]+)?$|^.* (\+Inf|-Inf|NaN)$')


def check_prometheus(path: str, *, expect_phases) -> None:
    families = {}
    with open(path) as fh:
        for ln, line in enumerate(fh, 1):
            line = line.rstrip("\n")
            if not line:
                continue
            if line.startswith("# HELP ") or line.startswith("# TYPE "):
                parts = line.split(" ", 3)
                assert len(parts) >= 3, f"{path}:{ln}: bad comment {line!r}"
                if parts[1] == "TYPE":
                    assert parts[3] in ("counter", "gauge", "histogram"), \
                        f"{path}:{ln}: bad kind {parts[3]!r}"
                    families[parts[2]] = parts[3]
                continue
            assert SERIES_RE.match(line), f"{path}:{ln}: bad series {line!r}"
    for name, kind in families.items():
        assert name.startswith("repro_"), f"unprefixed family {name}"
        if kind == "counter":
            assert name.endswith("_total"), f"counter w/o _total: {name}"

    # Router invariant: every phase's per-expert counts sum to
    # top_k * routed tokens, integer-exact.
    per_phase_experts: dict = {}
    per_phase_routed: dict = {}
    with open(path) as fh:
        for line in fh:
            m = re.match(r'^repro_router_expert_tokens_total'
                         r'\{phase="([^"]+)",expert="\d+"\} (\d+)$', line)
            if m:
                per_phase_experts[m.group(1)] = (
                    per_phase_experts.get(m.group(1), 0) + int(m.group(2)))
            m = re.match(r'^repro_router_routed_tokens_total'
                         r'\{phase="([^"]+)"\} (\d+)$', line)
            if m:
                per_phase_routed[m.group(1)] = int(m.group(2))
    for phase, top_k in expect_phases.items():
        assert phase in per_phase_experts, f"no expert counts for {phase}"
        got, routed = per_phase_experts[phase], per_phase_routed[phase]
        assert got == top_k * routed, (
            f"{phase}: sum(expert_tokens)={got} != "
            f"top_k*routed={top_k * routed}")
    print(f"PASS prometheus {os.path.basename(path)} "
          f"({len(families)} families, phases {sorted(expect_phases)})")


def check_trace(path: str, *, min_coverage: float = 0.95) -> None:
    from repro.obs.tracing import chrome_span_coverage
    with open(path) as fh:
        trace = json.load(fh)
    evs = trace["traceEvents"]
    assert evs, "empty trace"
    for e in evs:
        for key in ("name", "ph", "pid", "tid", "ts"):
            assert key in e, f"event missing {key}: {e}"
        assert e["ts"] >= 0
        assert e["ph"] in ("X", "i"), f"unexpected phase {e['ph']!r}"
        if e["ph"] == "X":
            assert e["dur"] >= 0
    cov = chrome_span_coverage(trace)
    assert cov >= min_coverage, f"span coverage {cov:.1%} < {min_coverage:.0%}"
    print(f"PASS trace {os.path.basename(path)} "
          f"({len(evs)} events, coverage {cov:.1%})")


def check_events(path: str) -> None:
    records = []
    with open(path) as fh:
        for ln, line in enumerate(fh, 1):
            rec = json.loads(line)
            assert "kind" in rec and "t" in rec, f"{path}:{ln}: {rec}"
            records.append(rec)
    print(f"PASS events {os.path.basename(path)} ({len(records)} records)")


def run_train(tmp: str) -> dict:
    prom = os.path.join(tmp, "train_prom.txt")
    trace = os.path.join(tmp, "train_trace.json")
    events = os.path.join(tmp, "train_events.jsonl")
    subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "mixtral-8x7b", "--smoke", "--steps", "4",
         "--ckpt-dir", os.path.join(tmp, "ckpt"),
         "--metrics", prom, "--metrics-interval", "2",
         "--trace-out", trace, "--events-out", events],
        check=True, cwd=ROOT,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    return {"prom": prom, "trace": trace, "events": events}


def run_serve(tmp: str) -> dict:
    from repro import obs
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.launch import serve
    from repro.models import lm
    from repro.parallel.sharding import ParallelConfig, split_tree
    import jax
    import numpy as np

    cfg = ModelConfig(
        name="obs-check-moe", family="moe",
        num_layers=2, d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
        d_ff=0, vocab_size=64, dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=64),
    )
    pcfg = ParallelConfig(blk=8, collect_router_stats=True)
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    obs.configure(metrics=True, tracing=True, event_log=True, reset=True)
    srv = serve.PagedServer(
        cfg, pcfg, None, num_slots=2, page_size=4, num_pages=32,
        max_pages_per_slot=8, params=params, prefill_chunk=4)
    rng = np.random.default_rng(5)
    for i in range(4):
        srv.submit(serve.Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(3, 10))).astype(
                                    np.int32),
            max_new=int(rng.integers(2, 5)), out=[]))
    srv.run()
    prom = os.path.join(tmp, "serve_prom.txt")
    trace = os.path.join(tmp, "serve_trace.json")
    events = os.path.join(tmp, "serve_events.jsonl")
    if srv.router_drain is not None:
        srv.router_drain.flush()
    obs.registry.collect()
    obs.dump_prometheus(obs.registry, prom)
    obs.tracer.write(trace)
    obs.events.write_jsonl(events)
    obs.configure(metrics=False, tracing=False, event_log=False, reset=True)
    return {"prom": prom, "trace": trace, "events": events,
            "top_k": cfg.moe.top_k}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keep", action="store_true",
                    help="leave the artifacts in a printed tempdir")
    args = ap.parse_args()
    tmp = tempfile.mkdtemp(prefix="obs_check_")
    train = run_train(tmp)
    check_prometheus(train["prom"], expect_phases={"train": 2})
    check_trace(train["trace"])
    check_events(train["events"])
    srv = run_serve(tmp)
    check_prometheus(srv["prom"], expect_phases={"serve": srv["top_k"]})
    check_trace(srv["trace"])
    check_events(srv["events"])
    if args.keep:
        print(f"artifacts kept in {tmp}")
    else:
        import shutil
        shutil.rmtree(tmp, ignore_errors=True)
    print("obs-check: all artifacts valid")
    return 0


if __name__ == "__main__":
    sys.exit(main())
