#!/usr/bin/env python
"""Batch driver for the multi-pod dry-run: every (arch x shape x mesh) cell
in its own subprocess, JSON-cached so the sweep is resumable.

  python scripts/run_dryruns.py [--mesh single|multi|both] [--force]
        [--archs a,b] [--shapes s1,s2] [--timeout 3600]
"""
import argparse
import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "experiments", "dryrun")

ARCHS = [
    "qwen3_moe_30b_a3b", "mixtral_8x7b", "jamba_1_5_large_398b",
    "phi3_medium_14b", "starcoder2_15b", "gemma3_12b", "gemma_2b",
    "musicgen_large", "xlstm_350m", "paligemma_3b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def cell_path(arch, shape, mesh):
    return os.path.join(OUT, f"{arch}__{shape}__{mesh}.json")


def run_cell(arch, shape, mesh, timeout, extra):
    out = cell_path(arch, shape, mesh)
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--out", out,
    ] + (["--multi-pod", "--scan"] if mesh == "multi" else []) + extra
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    t0 = time.time()
    try:
        res = subprocess.run(cmd, capture_output=True, text=True,
                             timeout=timeout, env=env, cwd=ROOT)
        ok = res.returncode == 0 and os.path.exists(out)
        if not ok:
            with open(out, "w") as f:
                json.dump({"status": "error",
                           "stderr": res.stderr[-4000:],
                           "stdout": res.stdout[-1000:]}, f, indent=1)
        return ok, time.time() - t0
    except subprocess.TimeoutExpired:
        with open(out, "w") as f:
            json.dump({"status": "timeout", "timeout_s": timeout}, f)
        return False, time.time() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--archs", default=None)
    ap.add_argument("--shapes", default=None)
    ap.add_argument("--timeout", type=int, default=5400)
    ap.add_argument("--extra", default="", help="extra dryrun args")
    args = ap.parse_args()

    os.makedirs(OUT, exist_ok=True)
    archs = args.archs.split(",") if args.archs else ARCHS
    shapes = args.shapes.split(",") if args.shapes else SHAPES
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    extra = args.extra.split() if args.extra else []

    todo = []
    for mesh in meshes:
        for arch in archs:
            for shape in shapes:
                p = cell_path(arch, shape, mesh)
                if args.force or not os.path.exists(p) or _is_error(p):
                    todo.append((arch, shape, mesh))
    print(f"{len(todo)} cells to run "
          f"({len(archs) * len(shapes) * len(meshes) - len(todo)} cached)")
    for i, (arch, shape, mesh) in enumerate(todo):
        ok, dt = run_cell(arch, shape, mesh, args.timeout, extra)
        status = "OK " if ok else "FAIL"
        print(f"[{i + 1}/{len(todo)}] {status} {arch} {shape} {mesh} "
              f"({dt:.0f}s)", flush=True)


def _is_error(path):
    try:
        with open(path) as f:
            return json.load(f).get("status") in ("error", "timeout")
    except Exception:  # noqa: BLE001
        return True


if __name__ == "__main__":
    main()
