#!/usr/bin/env python
"""Validate BENCH_*.json files against the documented schema (README.md
"Benchmark JSON schema"): a top-level ``meta`` object (generated, grid,
suites, failed_suites, jax, backend) and a ``results`` mapping of
``name -> {us_per_call: number, derived: string}``.

Usage:
  python scripts/validate_bench.py BENCH_kernels.json BENCH_hetero.json \
      [--require PREFIX ...] [--lt NAME_A:NAME_B ...]

``--require PREFIX`` additionally demands at least one result row whose
name starts with PREFIX (CI uses it to pin the hetero uniform/proportional
rows so the executed Fig. 11 comparison can't silently vanish).

``--lt NAME_A:NAME_B`` demands both rows exist and A's numeric value is
strictly below B's (CI pins the serving claim "paged peak KV-cache bytes <
dense rectangle bytes" from the emitted JSON itself, not just from the
in-suite assert).
"""
from __future__ import annotations

import argparse
import json
import numbers
import sys

META_KEYS = ("generated", "grid", "suites", "failed_suites", "jax", "backend")


def validate(path: str) -> tuple[dict, list]:
    errors = []
    try:
        with open(path) as fh:
            payload = json.load(fh)
    except (OSError, ValueError) as exc:
        return {}, [f"{path}: unreadable ({exc})"]
    meta = payload.get("meta")
    if not isinstance(meta, dict):
        errors.append(f"{path}: missing 'meta' object")
    else:
        for key in META_KEYS:
            if key not in meta:
                errors.append(f"{path}: meta missing '{key}'")
        if meta.get("failed_suites"):
            errors.append(f"{path}: failed suites {meta['failed_suites']}")
    results = payload.get("results")
    if not isinstance(results, dict) or not results:
        errors.append(f"{path}: missing/empty 'results' mapping")
        return payload, errors
    for name, row in results.items():
        if not isinstance(row, dict):
            errors.append(f"{path}: result '{name}' is not an object")
            continue
        if not isinstance(row.get("us_per_call"), numbers.Number):
            errors.append(f"{path}: '{name}'.us_per_call is not a number")
        if not isinstance(row.get("derived"), str):
            errors.append(f"{path}: '{name}'.derived is not a string")
    return payload, errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+")
    ap.add_argument("--require", action="append", default=[],
                    help="result-name prefix that must be present "
                         "(in at least one file)")
    ap.add_argument("--lt", action="append", default=[],
                    help="NAME_A:NAME_B — both rows must exist and A's "
                         "numeric value must be strictly below B's")
    args = ap.parse_args(argv)
    errors = []
    names: list[str] = []
    values: dict = {}
    for path in args.files:
        payload, errs = validate(path)
        errors += errs
        for name, row in (payload.get("results", {}) or {}).items():
            names.append(name)
            if isinstance(row, dict) and isinstance(
                    row.get("us_per_call"), numbers.Number):
                values[name] = row["us_per_call"]
    for prefix in args.require:
        if not any(n.startswith(prefix) for n in names):
            errors.append(f"required result prefix missing: {prefix!r}")
    for pair in args.lt:
        a, _, b = pair.partition(":")
        if a not in values or b not in values:
            errors.append(f"--lt {pair}: missing row(s)")
        elif not values[a] < values[b]:
            errors.append(
                f"--lt {pair}: {values[a]} is not below {values[b]}")
    if errors:
        for e in errors:
            print(f"validate_bench: {e}", file=sys.stderr)
        return 1
    print(f"validate_bench: {len(args.files)} file(s), "
          f"{len(names)} rows, schema OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
