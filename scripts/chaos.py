#!/usr/bin/env python
"""Scripted chaos scenarios (`make chaos`, DESIGN.md §9).

Each scenario installs a seeded ``runtime.faults.FaultPlan`` and drives a
real driver end-to-end, asserting BIT-EXACT recovery against an unfaulted
reference — not merely survival:

  training-fallback   step failure while the newest checkpoint is
                      bit-flipped at commit -> fallback restore from the
                      older valid checkpoint -> trajectory identical to
                      the unfaulted run
  serving-retry       mid-decode + mid-prefill injected failures and an
                      engine-level re-jit -> every greedy stream
                      token-identical to the no-fault reference, with the
                      page-pool structural oracle audited every step
  serving-shrink      injected device dropout -> live requests carried
                      across ``PagedServer._shrink`` (pool reshared over
                      the surviving class) -> reference-identical streams
  train-elastic       subprocess with 8 fake devices: ``--elastic
                      --fault-spec`` device dropout on a 2x2 MoE mesh ->
                      ``choose_mesh_shape`` re-mesh over the survivors ->
                      checkpoint restore -> run completes

The same scenarios are pinned as tests in tests/test_chaos.py; this
driver is the operator-facing entry point (tier-2, wired into
scripts/ci.sh) and prints one PASS line per scenario.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import subprocess
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs as cfglib  # noqa: E402
from repro.core import hetero as hetero_lib  # noqa: E402
from repro.launch import serve, steps as steps_lib  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.parallel.sharding import ParallelConfig, split_tree  # noqa: E402
from repro.runtime import faults as faults_lib  # noqa: E402
from repro.runtime import ft as ft_lib  # noqa: E402

MAX_SEQ = 32


# ---------------------------------------------------------------------------
# training: corrupt newest checkpoint + step failure -> fallback restore
# ---------------------------------------------------------------------------

def _train_step(state, step):
    faults_lib.inject("train.step")
    return ({"x": state["x"] + jnp.float32(step + 1)},
            {"loss": float(step)})


def _train_run(ckpt_dir, steps=8):
    ft = ft_lib.FTConfig(ckpt_dir=ckpt_dir, save_every=2, keep=3,
                         backoff_base_s=0.0)
    return ft_lib.run_with_recovery(
        state={"x": jnp.float32(0.0)}, step_fn=_train_step, start_step=0,
        num_steps=steps, ft=ft, sleep_fn=lambda s: None)


def scenario_training_fallback() -> None:
    with tempfile.TemporaryDirectory() as td:
        ref_state, _ = _train_run(os.path.join(td, "ref"))
        plan = faults_lib.FaultPlan([
            faults_lib.Fault(site="ckpt.write", kind="bitflip", at=1,
                             payload={"leaf": 0}),
            faults_lib.Fault(site="train.step", kind="error", at=5),
        ])
        with faults_lib.scope(plan):
            state, last = _train_run(os.path.join(td, "chaos"))
        assert last == 8 and len(plan.fired) == 2, plan.fired
        np.testing.assert_array_equal(np.asarray(state["x"]),
                                      np.asarray(ref_state["x"]))


# ---------------------------------------------------------------------------
# serving scenarios
# ---------------------------------------------------------------------------

def _engine_setup():
    cfg = dataclasses.replace(cfglib.get_smoke_config("gemma-2b"),
                              dtype="float32")
    pcfg = ParallelConfig(blk=8, impl="pallas")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, pcfg, params


def _requests(cfg, specs, seed=5):
    rng = np.random.default_rng(seed)
    return [
        serve.Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=plen).astype(
                np.int32),
            max_new=max_new)
        for i, (plen, max_new) in enumerate(specs)
    ]


def _refs(cfg, pcfg, params, reqs):
    step = jax.jit(steps_lib.make_serve_step(
        cfg, pcfg, None, (1, 1, cfg.d_model)))
    return {r.rid: serve.greedy_reference(
        cfg, pcfg, None, params, r.prompt, r.max_new, max_seq=MAX_SEQ,
        step=step) for r in reqs}


def _check_streams(server, done, reqs, refs):
    assert server.failed == [], [r.error for r in server.failed]
    assert len(done) == len(reqs), (len(done), len(reqs))
    for r in done:
        assert r.out == refs[r.rid], f"rid={r.rid} diverged"
    server.assert_page_invariants()
    server.drop_prefix_cache()
    assert server.pool.free_pages == sum(server.pool.shares)


def scenario_serving_retry() -> None:
    cfg, pcfg, params = _engine_setup()
    reqs = _requests(cfg, [(6, 5), (9, 4), (7, 4), (11, 3), (6, 4)])
    refs = _refs(cfg, pcfg, params, reqs)
    plan = faults_lib.FaultPlan([
        faults_lib.Fault(site="serve.decode", kind="error", at=2,
                         payload={"slot": 0}),
        faults_lib.Fault(site="serve.prefill", kind="error", at=4,
                         payload={"slot": 1}),
        faults_lib.Fault(site="serve.decode", kind="error", at=9),
    ])
    maxp = MAX_SEQ // 4
    srv = serve.PagedServer(
        cfg, pcfg, None, num_slots=3, page_size=4, num_pages=1 + 3 * maxp,
        max_pages_per_slot=maxp, params=params, prefill_chunk=5,
        prefix_cache=True, audit=True)
    for r in reqs:
        srv.submit(dataclasses.replace(r, out=[]))
    with faults_lib.scope(plan):
        done = srv.run()
    assert len(plan.fired) == 3, plan.fired
    assert srv.aborts == 2 and srv.engine_recoveries == 1, srv.stats()
    _check_streams(srv, done, reqs, refs)


def scenario_serving_shrink() -> None:
    cfg, pcfg, params = _engine_setup()
    plan_h = hetero_lib.make_hetero_plan((1.0, 2.0), global_batch=4)
    reqs = _requests(cfg, [(6, 4), (9, 3), (7, 4), (5, 5), (6, 3),
                           (10, 4)])
    refs = _refs(cfg, pcfg, params, reqs)
    fplan = faults_lib.FaultPlan([
        faults_lib.Fault(site="serve.decode", kind="device_drop", at=3,
                         payload={"survivors": [0]}),
    ])
    maxp = MAX_SEQ // 4
    srv = serve.PagedServer(
        cfg, pcfg, None, num_slots=4, page_size=4, num_pages=1 + 4 * maxp,
        max_pages_per_slot=maxp, params=params, prefill_chunk=5,
        plan=plan_h, prefix_cache=True, audit=True)
    for r in reqs:
        srv.submit(dataclasses.replace(r, out=[]))
    with faults_lib.scope(fplan):
        done = srv.run()
    assert ("shrink", (0,)) in srv.trace
    assert len(srv.pool.shares) == 1
    _check_streams(srv, done, reqs, refs)


# ---------------------------------------------------------------------------
# training CLI: device dropout -> elastic re-mesh (subprocess, 8 devices)
# ---------------------------------------------------------------------------

def scenario_train_elastic() -> None:
    spec = ('{"faults": [{"site": "train.step", "kind": "device_drop",'
            ' "at": 3, "payload": {"survivors": 2}}]}')
    with tempfile.TemporaryDirectory() as td:
        code = f"""
from repro.launch import train
train.main([
    "--arch", "qwen3-moe-30b-a3b", "--smoke",
    "--steps", "6", "--global-batch", "4", "--seq-len", "16",
    "--mesh", "2,2", "--elastic", "--save-every", "2",
    "--ckpt-dir", {os.path.join(td, "ckpt")!r},
    "--fault-spec", {spec!r},
])
"""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        env["JAX_PLATFORMS"] = "cpu"
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        res = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             timeout=900)
        assert res.returncode == 0, res.stderr[-3000:]
        assert "[elastic] device loss -> re-mesh" in res.stdout, res.stdout
        assert "[train] finished at step 6" in res.stdout, res.stdout


SCENARIOS = {
    "training-fallback": scenario_training_fallback,
    "serving-retry": scenario_serving_retry,
    "serving-shrink": scenario_serving_shrink,
    "train-elastic": scenario_train_elastic,
}


def main(argv=None) -> int:
    """Run the named chaos scenarios (default: all), one PASS line each."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", action="append", choices=sorted(SCENARIOS),
                    help="run only these scenarios (repeatable)")
    args = ap.parse_args(argv)
    names = args.scenario or sorted(SCENARIOS)
    for name in names:
        SCENARIOS[name]()
        print(f"[chaos] {name}: PASS")
    print(f"[chaos] {len(names)}/{len(names)} scenarios passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
