"""Paper Table 8 / Figure 9 — train-step latency, Swin-MoE, 4 experts.

Real wall-clock on CPU at reduced scale: the claim to reproduce is the
RANKING (hexa < megablocks/tutel) and the gap's growth with batch size.
us_per_call is the measured median step time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from benchmarks.memory_table import bench_cfg, make_train_fn
from repro.models import swin
from repro.optim import adamw
from repro.parallel.sharding import ParallelConfig, split_tree


def run(quick: bool = True):
    topks = [1, 2] if quick else [1, 2, 3, 4]
    batch = 8 if quick else 32
    rows = []
    for k in topks:
        cfg = bench_cfg("small", 4, k)
        params, _ = split_tree(swin.init_swin(jax.random.PRNGKey(0), cfg))
        pcfg = ParallelConfig(blk=16, capacity_factor=1.25)
        rng = np.random.default_rng(0)
        images = jnp.asarray(
            rng.normal(size=(batch, cfg.img_size, cfg.img_size, 3)),
            jnp.float32)
        labels = jnp.asarray(rng.integers(0, cfg.num_classes, batch))
        base_us = None
        for mname in ("tutel", "megablocks", "hexa"):
            train, opt_cfg = make_train_fn(cfg, pcfg, mname)
            opt = adamw.init_opt_state(params, opt_cfg)
            jit = jax.jit(train)
            us = time_fn(jit, params, opt, images, labels, iters=3, warmup=1)
            if mname == "tutel":
                base_us = us
            rows.append((k, mname, us))
            emit(f"latency_T8/top{k}/{mname}", us,
                 f"speedup_vs_tutel={base_us / us:.2f}x")
    return rows


if __name__ == "__main__":
    run()
