"""Observability overhead benchmark (ISSUE 10, DESIGN.md §12) — emitted
to ``BENCH_obs.json`` via the per-suite routing in ``benchmarks/run.py``.

The subsystem's contract is "free when off, cheap when on":

  * ``obs/overhead/{off_us,on_us}`` — one full jitted train step (fwd +
    bwd + optimizer) with ``collect_router_stats`` off vs on, interleaved
    A/B so machine-load drift cancels. The on-path includes everything
    the real driver pays: the device-side accumulators in every MoE
    layer, the drain push, and the host-side span around the step.
  * ``obs/overhead/step_ratio`` — median per-round on/off ratio; the
    ``--lt`` pin in ``make bench-check`` holds it under
    ``obs/overhead/limit`` (1.03x, the ISSUE 10 acceptance budget).
  * ``obs/registry/noop_inc_us`` — cost of 1000 counter increments on a
    DISABLED registry (the flag-check fast path instrumented library
    code pays in production runs with observability off).
  * ``obs/registry/inc_us`` — the same 1000 increments enabled, for
    scale (informational).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, time_pair
from repro import obs
from repro.configs.base import ModelConfig, MoEConfig
from repro.data.pipeline import DataConfig, TokenSource
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.obs.metrics import MetricsRegistry
from repro.optim import adamw
from repro.parallel.sharding import ParallelConfig, split_tree


def _registry_rows() -> None:
    for name, reg in (("noop_inc", MetricsRegistry(enabled=False)),
                      ("inc", MetricsRegistry(enabled=True))):
        fam = reg.counter("repro_bench_ops_total", "bench", labels=("k",))
        t0 = time.perf_counter()
        for _ in range(1000):
            fam.labels("a").inc()
        us = (time.perf_counter() - t0) * 1e6
        emit(f"obs/registry/{name}_us", us, "per 1000 labeled incs")


def run(quick: bool = True) -> None:
    _registry_rows()

    b, s = (8, 64) if quick else (16, 128)
    cfg = ModelConfig(
        name="obs-bench", family="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=0, vocab_size=256, dtype="float32",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=256),
    )
    import dataclasses
    pcfg_off = ParallelConfig(blk=32)
    pcfg_on = dataclasses.replace(pcfg_off, collect_router_stats=True)
    opt_cfg = adamw.OptimizerConfig(peak_lr=1e-3, warmup_steps=5,
                                    decay_steps=100, master_fp32=False)
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    opt = adamw.init_opt_state(params, opt_cfg)
    shape = (b, s, cfg.d_model)
    step_off = jax.jit(
        steps_lib.make_train_step(cfg, pcfg_off, None, opt_cfg, shape))
    step_on = jax.jit(
        steps_lib.make_train_step(cfg, pcfg_on, None, opt_cfg, shape))
    batch = TokenSource(DataConfig(seq_len=s, global_batch=b,
                                   vocab_size=cfg.vocab_size)).batch(0)
    batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}

    reg = MetricsRegistry(enabled=True)
    drain = obs.RouterStatsDrain(reg, cfg.moe.num_experts, phase="bench")
    tracer = obs.Tracer(enabled=True)

    def run_off():
        _, _, m = step_off(params, opt, batch)
        return m["loss"]

    def run_on():
        # Everything the instrumented driver pays per step: the span, the
        # extra jit outputs, and the O(1) drain push.
        with tracer.span("train.step"):
            _, _, m = step_on(params, opt, batch)
            drain.push(m.pop("router_stats"))
            return m["loss"]

    on_us, off_us, ratio = time_pair(run_on, run_off, rounds=16)
    drain.flush()
    emit("obs/overhead/off_us", off_us, "train step, stats off")
    emit("obs/overhead/on_us", on_us, "train step, stats+span+drain on")
    # Percent, not raw ratio: the JSON writer rounds values to one
    # decimal, which would collapse 0.98x and the 1.03x ceiling both to
    # 1.0 and void the --lt pin.
    emit("obs/overhead/step_ratio", 100.0 * ratio,
         "on/off percent; budget 103 (DESIGN.md §12)")
    emit("obs/overhead/limit", 103.0,
         "acceptance ceiling for step_ratio (percent)")

    # Sanity on the measured path: the drain really saw routed tokens.
    routed = reg.value("repro_router_routed_tokens_total", "bench")
    n_moe = sum(1 for i in range(cfg.num_layers) if cfg.is_moe_layer(i))
    expect_per_step = b * s * n_moe
    assert routed > 0 and routed % expect_per_step == 0, (
        routed, expect_per_step)
