"""Kernel microbenchmarks: expert-specific op implementations on CPU.

us_per_call for esmm / esfk across impls. 'pallas' runs in interpret mode
here (correctness path; its TPU perf story is the dry-run roofline —
interpret timing is NOT representative). 'blocked' is the fair CPU
execution path; 'dense_ep' computes every expert densely (the redundancy
the paper removes) as the flop baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.reindex import build_reindex, gather_sorted
from repro.kernels import ops


def run(quick: bool = True):
    n, d, f, e, k, blk = (1024, 256, 512, 8, 2, 64)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    ei = jax.random.randint(ks[0], (n, k), 0, e)
    g = jax.random.uniform(ks[1], (n, k))
    ri = build_reindex(ei, g, e, blk)
    x = jax.random.normal(ks[2], (n, d), jnp.float32)
    xs = gather_sorted(x, ri)
    w = jax.random.normal(ks[3], (e, d, f)) * 0.1

    impls = ["blocked", "ragged"] + ([] if quick else ["pallas"])
    base = None
    for impl in impls:
        fn = jax.jit(
            lambda xs, w: ops.esmm(xs, w, None, ri.block_expert,
                                   ri.padded_counts, impl=impl)
        )
        us = time_fn(fn, xs, w, iters=5, warmup=2)
        if base is None:
            base = us
        emit(f"kernel/esmm/{impl}", us, f"rows={ri.num_rows};D={d};F={f}")

    # dense every-expert baseline (zero-redundancy counterpoint)
    dense = jax.jit(lambda x, w: jnp.einsum("nd,edf->nef", x, w))
    us = time_fn(dense, x, w, iters=3, warmup=1)
    emit("kernel/esmm/dense_all_experts", us,
         f"redundancy={e}/{k}={e / k:.0f}x")

    # fused backward
    dy = jax.random.normal(jax.random.PRNGKey(9), (ri.num_rows, f))
    for impl in impls:
        fn = jax.jit(
            lambda xs, dy: ops.esfk(xs, dy, ri.block_expert,
                                    ri.padded_counts, impl=impl)
        )
        us = time_fn(fn, xs, dy, iters=5, warmup=2)
        emit(f"kernel/esfk/{impl}", us, "dW+db fused")


if __name__ == "__main__":
    run()
