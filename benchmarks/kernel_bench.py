"""Kernel microbenchmarks: expert-specific op implementations on CPU.

us_per_call for esmm / esfk across impls, plus the fused expert-FFN
(``esffn``, DESIGN.md §5) against the unfused gather/esmm/act/esmm/combine
composition at the ``espec.moe_glu`` / ``moe_mlp`` level. 'pallas' runs in
interpret mode here (correctness path; its TPU perf story is the dry-run
roofline — interpret timing is NOT representative). 'blocked' is the fair
CPU execution path; 'dense_ep' computes every expert densely (the
redundancy the paper removes) as the flop baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn, time_pair
from repro.core import espec
from repro.core.reindex import build_reindex, gather_sorted
from repro.kernels import ops


def run(quick: bool = True):
    n, d, f, e, k, blk = (1024, 256, 512, 8, 2, 64)
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    ei = jax.random.randint(ks[0], (n, k), 0, e)
    g = jax.random.uniform(ks[1], (n, k))
    ri = build_reindex(ei, g, e, blk)
    x = jax.random.normal(ks[2], (n, d), jnp.float32)
    xs = gather_sorted(x, ri)
    w = jax.random.normal(ks[3], (e, d, f)) * 0.1

    impls = ["blocked", "ragged"] + ([] if quick else ["pallas"])
    for impl in impls:
        fn = jax.jit(
            lambda xs, w: ops.esmm(xs, w, None, ri.block_expert,
                                   ri.padded_counts, impl=impl)
        )
        us = time_fn(fn, xs, w, iters=5, warmup=2)
        emit(f"kernel/esmm/{impl}", us, f"rows={ri.num_rows};D={d};F={f}")

    # dense every-expert baseline (zero-redundancy counterpoint)
    dense = jax.jit(lambda x, w: jnp.einsum("nd,edf->nef", x, w))
    us = time_fn(dense, x, w, iters=3, warmup=1)
    emit("kernel/esmm/dense_all_experts", us,
         f"redundancy={e}/{k}={e / k:.0f}x")

    # fused backward
    dy = jax.random.normal(jax.random.PRNGKey(9), (ri.num_rows, f))
    for impl in impls:
        fn = jax.jit(
            lambda xs, dy: ops.esfk(xs, dy, ri.block_expert,
                                    ri.padded_counts, impl=impl)
        )
        us = time_fn(fn, xs, dy, iters=5, warmup=2)
        emit(f"kernel/esfk/{impl}", us, "dW+db fused")

    # fused forward FFN (esffn megakernel shape) vs the unfused composition,
    # measured end-to-end at the espec.moe_* level on the blocked CPU path.
    wg = jax.random.normal(ks[4], (e, d, f)) * 0.1
    wu = jax.random.normal(ks[5], (e, d, f)) * 0.1
    wd = jax.random.normal(ks[6], (e, f, d)) * 0.1
    b1 = jax.random.normal(ks[7], (e, f)) * 0.1
    b2 = jnp.zeros((e, d))
    bodies = {
        "moe_glu": (
            lambda fused: jax.jit(lambda x, a, b, c: espec.moe_glu(
                x, ri, a, b, c, act="silu", impl="blocked", fused=fused)),
            (x, wg, wu, wd),
        ),
        "moe_mlp": (
            lambda fused: jax.jit(lambda x, a, b, c, dd: espec.moe_mlp(
                x, ri, a, b, c, dd, act="gelu", impl="blocked", fused=fused)),
            (x, wg, b1, wd, b2),
        ),
    }
    for name, (mk, args) in bodies.items():
        # Interleaved A/B so machine-load drift cannot skew the ratio.
        us_u, us_f, speedup = time_pair(mk(False), mk(True), *args, rounds=16)
        emit(f"kernel/{name}/blocked_unfused", us_u,
             f"rows={ri.num_rows};D={d};F={f}")
        emit(f"kernel/{name}/blocked_fused", us_f,
             f"speedup_vs_unfused={speedup:.2f}x")


if __name__ == "__main__":
    run()
