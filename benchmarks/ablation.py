"""Paper Figure 12 — ablations: pipeline-shared cache, fused backward
kernel, memory-latency trade-off.

  * cache (janus vs shared_cache): peak memory of a train step on an
    8-fake-device mesh (subprocess via the dryrun harness on the smoke
    config) — Janus retains gathered expert params for backward, the
    shared cache re-gathers.
  * fused kernel (ESFK vs ESTMM+ESS): wall time of the MoE backward.
  * memopt (scatter-add combine vs per-choice materialisation): peak
    memory of the MoE FFN fwd+bwd.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_stats, emit, time_fn
from repro.core import espec
from repro.core.reindex import build_reindex, combine_scatter, gather_sorted
from repro.core.routing import route
from repro.kernels import ops

N, D, F, E, K, BLK = 512, 128, 256, 8, 4, 32


def _setup(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (N, D))
    p = {
        "router": jax.random.normal(ks[1], (D, E)) * 0.2,
        "w1": jax.random.normal(ks[2], (E, D, F)) * 0.2,
        "b1": jnp.zeros((E, F)),
        "w2": jax.random.normal(ks[3], (E, F, D)) * 0.2,
        "b2": jnp.zeros((E, D)),
    }
    return x, p


def bench_fused_kernel():
    x, p = _setup()

    def loss(p, fused):
        out = espec.hexa_moe_ffn(
            x, p, num_experts=E, top_k=K, act="gelu", glu=False, blk=BLK,
            impl="pallas",
        )
        return jnp.sum(out.y ** 2)

    for fused in (True, False):
        ops.set_fused_backward(fused)
        g = jax.jit(jax.grad(lambda p: loss(p, fused)))
        us = time_fn(g, p, iters=3, warmup=1)
        emit(f"ablation_F12/fused_kernel/{'esfk' if fused else 'unfused'}",
             us, "pallas interpret on CPU")
    ops.set_fused_backward(True)


def bench_memopt():
    x, p = _setup()

    def loss_memopt(p):
        out = espec.hexa_moe_ffn(
            x, p, num_experts=E, top_k=K, act="gelu", glu=False, blk=BLK,
            impl="blocked",
        )
        return jnp.sum(out.y ** 2)

    def loss_naive(p):
        # paper Fig. 5(a): one full ESMM pipeline per routing choice,
        # materialising k per-choice outputs before summation.
        r = route(x, p["router"], K)
        total = 0.0
        outs = []
        for s in range(K):
            ri = build_reindex(
                r.expert_idx[:, s:s + 1], r.gates[:, s:s + 1], E, BLK
            )
            xs = gather_sorted(x, ri)
            h = ops.esmm(xs, p["w1"], p["b1"], ri.block_expert,
                         ri.padded_counts, impl="blocked")
            h = jax.nn.gelu(h)
            ys = ops.esmm(h, p["w2"], p["b2"], ri.block_expert,
                          ri.padded_counts, impl="blocked")
            outs.append(combine_scatter(ys, ri, N))
        y = sum(outs)
        return jnp.sum(y ** 2)

    for name, fn in (("memopt", loss_memopt), ("naive_topk", loss_naive)):
        stats = compiled_stats(jax.grad(fn), p)
        emit(f"ablation_F12/memopt/{name}", 0.0,
             f"peak_MB={stats['peak_bytes'] / 1e6:.1f};"
             f"flops={stats['flops']:.3e}")


def bench_cache_policy():
    """shared_cache vs janus peak memory on an 8-device mesh (subprocess)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.launch import inputs as inputs_lib, steps as steps_lib
from repro.launch.mesh import make_mesh
from repro.configs.base import ShapeConfig
from repro.optim import adamw
from repro.parallel.sharding import ParallelConfig
import dataclasses

cfg = get_smoke_config("mixtral-8x7b")
cfg = dataclasses.replace(cfg, num_layers=4, d_model=256, vocab_size=512,
                          moe=dataclasses.replace(cfg.moe, d_ff=512))
shape = ShapeConfig("bench", "train", 512, 8)
mesh = make_mesh((2, 4), ("data", "model"))
out = {}
for policy in ("shared_cache", "janus", "none"):
    pcfg = ParallelConfig(mode="data_centric", cache_policy=policy,
                          remat="none" if policy == "none" else "block",
                          blk=32, impl="blocked", scan_layers=False)
    opt_cfg = adamw.OptimizerConfig(master_fp32=False)
    ap, _, _ = steps_lib.sharded_params(cfg, pcfg, mesh)
    batch = inputs_lib.input_specs(cfg, shape, pcfg, mesh)
    opt = steps_lib.sharded_opt_state(ap, opt_cfg, mesh)
    sf = steps_lib.make_train_step(cfg, pcfg, mesh, opt_cfg,
                                   (shape.global_batch, shape.seq_len, cfg.d_model))
    with mesh:
        c = jax.jit(sf).lower(ap, opt, batch).compile()
    ma = c.memory_analysis()
    out[policy] = ma.argument_size_in_bytes + ma.temp_size_in_bytes
print("RESULT" + json.dumps(out))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT")]
    if not line:
        emit("ablation_F12/cache_policy/ERROR", 0.0,
             res.stderr.strip()[-200:].replace(",", ";"))
        return
    out = json.loads(line[0][len("RESULT"):])
    for policy, peak in out.items():
        emit(f"ablation_F12/cache_policy/{policy}", 0.0,
             f"peak_MB={peak / 1e6:.1f}")


def run(quick: bool = True):
    bench_fused_kernel()
    bench_memopt()
    bench_cache_policy()


if __name__ == "__main__":
    run()
