"""Benchmark entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract and writes
the same results machine-readably to ``BENCH_kernels.json`` (``--json``),
so the per-PR perf trajectory accumulates alongside the stdout table.
``--full`` widens sweeps to the paper's full grids (slow on 1 CPU core).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

# Runnable both as `python -m benchmarks.run` and `python benchmarks/run.py`,
# with or without PYTHONPATH: suite modules need the repo root (for
# `benchmarks.*`) AND src/ (for `repro.*`) on the path.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)


def main() -> None:
    ap = argparse.ArgumentParser()
    grids = ap.add_mutually_exclusive_group()
    grids.add_argument("--full", action="store_true")
    grids.add_argument("--quick", action="store_true",
                       help="quick grids (the default; explicit flag for CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: kernel,hetero,centric,"
                         "memory,latency,ablation")
    ap.add_argument("--json", default=os.path.join(_ROOT, "BENCH_kernels.json"),
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args()
    quick = not args.full

    import jax

    from benchmarks import (
        ablation,
        centric_crossover,
        common as bench_common,
        hetero_alloc,
        kernel_bench,
        latency_table,
        memory_table,
    )

    suites = {
        "kernel": kernel_bench.run,
        "hetero": hetero_alloc.run,
        "centric": centric_crossover.run,
        "memory": memory_table.run,
        "latency": latency_table.run,
        "ablation": ablation.run,
    }
    wanted = args.only.split(",") if args.only else list(suites)
    bench_common.reset_records()
    print("name,us_per_call,derived")
    failed = []
    for name in wanted:
        try:
            suites[name](quick=quick)
        except Exception:  # noqa: BLE001 — report and continue
            failed.append(name)
            traceback.print_exc()
    if args.json:
        results = {
            r["name"]: {
                "us_per_call": round(r["us_per_call"], 1),
                "derived": r["derived"],
            }
            for r in bench_common.RECORDS
        }
        if (args.only or failed) and os.path.exists(args.json):
            # Subset or partially-failed run: refresh only the re-measured
            # rows, keep the rest of the accumulated trajectory.
            try:
                with open(args.json) as fh:
                    old = json.load(fh).get("results", {})
                results = {**old, **results}
            except (OSError, ValueError):
                pass
        payload = {
            "meta": {
                "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
                "grid": "full" if args.full else "quick",
                "suites": wanted,
                "failed_suites": failed,
                "jax": jax.__version__,
                "backend": jax.default_backend(),
            },
            "results": results,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(
            f"wrote {args.json} ({len(results)} entries, "
            f"{len(bench_common.RECORDS)} fresh)",
            file=sys.stderr,
        )
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
