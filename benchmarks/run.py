"""Benchmark entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract and writes
the same results machine-readably to per-suite JSON files (``--json`` names
the default file; suites listed in ``SUITE_JSON`` get their own, e.g. the
hetero suite -> ``BENCH_hetero.json``), so the per-PR perf trajectory
accumulates alongside the stdout table. Partial runs (``--only``, or a
failed suite) merge-preserve previously accumulated rows in EVERY file.
``--full`` widens sweeps to the paper's full grids (slow on 1 CPU core).
The schema (shared by all BENCH_*.json) is documented in README.md and
enforced by ``scripts/validate_bench.py`` in CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

# Runnable both as `python -m benchmarks.run` and `python benchmarks/run.py`,
# with or without PYTHONPATH: suite modules need the repo root (for
# `benchmarks.*`) AND src/ (for `repro.*`) on the path.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)


def main() -> None:
    ap = argparse.ArgumentParser()
    grids = ap.add_mutually_exclusive_group()
    grids.add_argument("--full", action="store_true")
    grids.add_argument("--quick", action="store_true",
                       help="quick grids (the default; explicit flag for CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: kernel,hetero,centric,"
                         "memory,latency,ablation,serve,quant,obs")
    ap.add_argument("--json", default=os.path.join(_ROOT, "BENCH_kernels.json"),
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args()
    quick = not args.full

    import jax

    from benchmarks import (
        ablation,
        centric_crossover,
        common as bench_common,
        hetero_alloc,
        kernel_bench,
        latency_table,
        memory_table,
        obs_bench,
        quant_bench,
        serve_bench,
    )

    suites = {
        "kernel": kernel_bench.run,
        "hetero": hetero_alloc.run,
        "centric": centric_crossover.run,
        "memory": memory_table.run,
        "latency": latency_table.run,
        "ablation": ablation.run,
        "serve": serve_bench.run,
        "quant": quant_bench.run,
        "obs": obs_bench.run,
    }
    wanted = args.only.split(",") if args.only else list(suites)
    bench_common.reset_records()
    print("name,us_per_call,derived")
    failed = []
    suite_rows = {}  # suite -> its slice of RECORDS
    for name in wanted:
        start = len(bench_common.RECORDS)
        try:
            suites[name](quick=quick)
        except Exception:  # noqa: BLE001 — report and continue
            failed.append(name)
            traceback.print_exc()
        suite_rows[name] = bench_common.RECORDS[start:]
    if args.json:
        json_dir = os.path.dirname(os.path.abspath(args.json))
        files = {}  # path -> (fresh results, suites that fed it)
        for name in wanted:
            path = (os.path.join(json_dir, SUITE_JSON[name])
                    if name in SUITE_JSON else args.json)
            res, fed = files.setdefault(path, ({}, []))
            fed.append(name)
            for r in suite_rows[name]:
                res[r["name"]] = {
                    "us_per_call": round(r["us_per_call"], 1),
                    "derived": r["derived"],
                }
        meta_base = {
            "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "grid": "full" if args.full else "quick",
            "jax": jax.__version__,
            "backend": jax.default_backend(),
        }
        for path, (results, fed) in files.items():
            merge = bool(args.only or any(s in failed for s in fed))
            _write_json(path, results, fed,
                        [s for s in failed if s in fed], meta_base, merge)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


#: Suites whose rows accumulate in their own file (everything else goes to
#: the --json default, BENCH_kernels.json).
SUITE_JSON = {"hetero": "BENCH_hetero.json", "serve": "BENCH_serve.json",
              "quant": "BENCH_quant.json", "obs": "BENCH_obs.json"}


def _write_json(path, results, suites, failed, meta_base, merge):
    """Write one BENCH_*.json, merge-preserving accumulated rows when the
    run was partial (--only subset or a failed suite)."""
    if merge and os.path.exists(path):
        try:
            with open(path) as fh:
                old = json.load(fh).get("results", {})
            results = {**old, **results}
        except (OSError, ValueError):
            pass
    payload = {
        "meta": {**meta_base, "suites": suites, "failed_suites": failed},
        "results": results,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {path} ({len(results)} entries)", file=sys.stderr)


if __name__ == "__main__":
    main()
