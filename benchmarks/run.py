"""Benchmark entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract.
``--full`` widens sweeps to the paper's full grids (slow on 1 CPU core).
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

# Runnable both as `python -m benchmarks.run` and `python benchmarks/run.py`,
# with or without PYTHONPATH: suite modules need the repo root (for
# `benchmarks.*`) AND src/ (for `repro.*`) on the path.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)


def main() -> None:
    ap = argparse.ArgumentParser()
    grids = ap.add_mutually_exclusive_group()
    grids.add_argument("--full", action="store_true")
    grids.add_argument("--quick", action="store_true",
                       help="quick grids (the default; explicit flag for CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: kernel,hetero,centric,"
                         "memory,latency,ablation")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        ablation,
        centric_crossover,
        hetero_alloc,
        kernel_bench,
        latency_table,
        memory_table,
    )

    suites = {
        "kernel": kernel_bench.run,
        "hetero": hetero_alloc.run,
        "centric": centric_crossover.run,
        "memory": memory_table.run,
        "latency": latency_table.run,
        "ablation": ablation.run,
    }
    wanted = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    failed = []
    for name in wanted:
        try:
            suites[name](quick=quick)
        except Exception:  # noqa: BLE001 — report and continue
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
