"""Paper Table 7 / Figure 8 — memory vs top-k for Swin-MoE.

Per-method peak training-step memory (params + optimizer + activations,
from AOT ``memory_analysis``) as routing scales top-1 -> top-k with 8
experts. The paper's claims to reproduce:

  * HEXA-MoE < MegaBlocks < Tutel at every k,
  * HEXA-MoE's growth with k is much flatter (only the hidden-token
    buffers grow; no (E,C,D) capacity buffers).

Scale note: CPU-compile forces a reduced Swin (the method ranking and the
k-trend are scale-independent; the paper's absolute GBs need the 24GB-GPU
setup). --full uses the paper's Swin-S/B dims.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_stats, emit
from repro.configs.base import MoEConfig
from repro.models import swin
from repro.optim import adamw
from repro.parallel.sharding import ParallelConfig, split_tree


def bench_cfg(scale: str, num_experts: int, top_k: int) -> swin.SwinConfig:
    if scale == "small":
        dims, heads = (32, 64, 128, 256), (2, 4, 4, 8)
    else:
        dims, heads = (48, 96, 192, 384), (2, 4, 8, 8)
    return swin.SwinConfig(
        name=f"swin-bench-{scale}",
        img_size=64,
        patch_size=4,
        depths=(1, 1, 4, 1),
        dims=dims,
        heads=heads,
        window=4,
        num_classes=100,
        moe=MoEConfig(num_experts=num_experts, top_k=top_k, d_ff=0),
    )


def make_train_fn(cfg, pcfg, moe_impl):
    opt_cfg = adamw.OptimizerConfig(master_fp32=False)

    def loss_fn(params, images, labels):
        logits, aux, z = swin.swin_forward(
            params, images, cfg, pcfg, None, moe_impl=moe_impl
        )
        onehot = jax.nn.one_hot(labels, cfg.num_classes)
        ce = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))
        return ce + 0.01 * aux

    def train(params, opt, images, labels):
        loss, grads = jax.value_and_grad(loss_fn)(params, images, labels)
        params, opt, _ = adamw.apply_updates(params, grads, opt, opt_cfg)
        return params, opt, loss

    return train, opt_cfg


def run(quick: bool = True, batch: int = 16):
    scales = ["small"] if quick else ["small", "base"]
    topks = [1, 2, 4, 8] if quick else [1, 2, 3, 4, 5, 6, 7, 8]
    methods = [
        ("tutel", dict(moe_impl="tutel")),
        ("megablocks", dict(moe_impl="megablocks")),
        ("hexa", dict(moe_impl="hexa")),
    ]
    rows = []
    for scale in scales:
        for k in topks:
            cfg = bench_cfg(scale, 8, k)
            params, _ = split_tree(swin.init_swin(jax.random.PRNGKey(0), cfg))
            images = jax.ShapeDtypeStruct(
                (batch, cfg.img_size, cfg.img_size, 3), jnp.float32)
            labels = jax.ShapeDtypeStruct((batch,), jnp.int32)
            for mname, kw in methods:
                # hexa memory is measured through the Pallas kernels (the
                # shipped path; the XLA 'blocked' stand-in carries tile
                # buffers a kernel never materialises).
                pcfg = ParallelConfig(
                    blk=16, capacity_factor=1.25,
                    impl="pallas" if kw["moe_impl"] == "hexa" else None,
                )
                train, opt_cfg = make_train_fn(cfg, pcfg, kw["moe_impl"])
                opt = adamw.init_opt_state(params, opt_cfg)
                stats = compiled_stats(train, params, opt, images, labels)
                mb = stats["peak_bytes"] / 1e6
                rows.append((scale, k, mname, mb))
                emit(f"memory_T7/{scale}/top{k}/{mname}", 0.0,
                     f"peak_MB={mb:.1f}")
    report_shared_cache_residency(quick=quick)
    # trend summary: ours flattest + smallest
    for scale in scales:
        by = {m: [r[3] for r in rows if r[0] == scale and r[2] == m]
              for m in ("tutel", "megablocks", "hexa")}
        growth = {m: v[-1] / v[0] for m, v in by.items()}
        emit(f"memory_T7/{scale}/summary", 0.0,
             f"hexa_vs_tutel_at_k{topks[-1]}="
             f"{by['hexa'][-1] / by['tutel'][-1]:.3f};"
             f"growth_hexa={growth['hexa']:.3f};"
             f"growth_tutel={growth['tutel']:.3f}")
    return rows


def report_shared_cache_residency(quick: bool = True):
    """Pipeline-shared cache residency (paper §4.5; DESIGN.md §2).

    Replays the unrolled layer loop's fetch/prefetch sequence through the
    REAL cache object for the Fig. 10 layer shape and reports its own
    accounting: peak resident gathered layers/bytes vs the Janus baseline
    (all layers resident). The bound is the claim: residency never exceeds
    the configured capacity no matter the depth.
    """
    import jax

    from repro.parallel.cache import PipelineSharedCache, gathered_layer_bytes

    d, f, e = 1024, 4096, 8          # the centric_crossover layer
    n_layers = 8 if quick else 32
    layer = {
        "w_gate": jax.ShapeDtypeStruct((e, d, f), jnp.bfloat16),
        "w_up": jax.ShapeDtypeStruct((e, d, f), jnp.bfloat16),
        "w_down": jax.ShapeDtypeStruct((e, f, d), jnp.bfloat16),
    }
    janus_mb = n_layers * gathered_layer_bytes(d, f, e, glu=True) / 1e6
    for cap in (1, 2, 4):
        cache = PipelineSharedCache(cap)
        for l in range(n_layers):
            cache.fetch(l, lambda: layer)
            if cap >= 2 and l + 1 < n_layers:
                cache.prefetch(l + 1, lambda: layer)
        st = cache.stats()
        assert st["peak_resident_layers"] <= cap
        emit(
            f"memory_T7/shared_cache/cap{cap}", 0.0,
            f"layers={n_layers};peak_layers={st['peak_resident_layers']};"
            f"peak_MB={st['peak_resident_bytes'] / 1e6:.1f};"
            f"janus_MB={janus_mb:.1f};"
            f"hits={st['hits']};misses={st['misses']};"
            f"prefetches={st['prefetches']};evictions={st['evictions']}",
        )


if __name__ == "__main__":
    run()
