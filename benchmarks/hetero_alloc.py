"""Paper Table 3 / Figure 11 — heterogeneous-aware allocation.

Reproduces the experiment logic exactly: measure per-device capacity with
the paper's proxy task (here: calibrated latency profiles for the paper's
three power-limit cases), sweep the division proportion, and verify the
latency minimum sits at the capacity proportion (Eq. 1/2), with the
paper's reported % gains over uniform division.

On real heterogeneous hardware the same code path measures t_i by timing
the proxy matmul loop per device (``measure_capacity``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.hetero import (
    DeviceProfile,
    plan_data_centric,
    plan_model_centric,
    proportional_split,
    step_latency_model,
)

# Paper Table 3: (P0, t0, P1, t1) per case.
PAPER_CASES = {
    "case1_100W_300W": (4.58, 3.06),   # R = (0.40, 0.60)
    "case2_300W_300W": (3.20, 3.18),   # R = (0.50, 0.50)
    "case3_300W_100W": (3.28, 9.42),   # R = (0.74, 0.26)
}


def measure_capacity(size: int = 512, times: int = 16) -> float:
    """The paper's Appendix-B proxy task (scaled)."""
    key = jax.random.PRNGKey(0)
    m1 = jax.random.normal(key, (size, size))
    m2 = jax.random.normal(key, (size, size))
    f = jax.jit(lambda a, b: a @ b)
    jax.block_until_ready(f(m1, m2))
    t0 = time.perf_counter()
    for _ in range(times):
        m1 = f(m1, m2) / size
    jax.block_until_ready(m1)
    return time.perf_counter() - t0


def run(quick: bool = True):
    rows = []
    emit("hetero_T3/proxy_task_local", measure_capacity() * 1e6,
         "paper Appendix-B proxy on this host")
    for case, (t0, t1) in PAPER_CASES.items():
        profiles = [DeviceProfile("D0", t0), DeviceProfile("D1", t1)]
        total = 120
        # sweep division proportions (Fig. 11 x-axis)
        sweep = []
        for share0 in range(10, total - 9, 10):
            shares = [share0, total - share0]
            sweep.append(
                (share0 / total, step_latency_model(profiles, shares, total))
            )
        best_prop, best_t = min(sweep, key=lambda x: x[1])
        plan = plan_data_centric(profiles, total)
        plan_t = step_latency_model(profiles, plan, total)
        uni_t = step_latency_model(profiles, [total // 2, total // 2], total)
        gain = (uni_t - plan_t) / uni_t * 100
        cap_prop = (1 / t0) / (1 / t0 + 1 / t1)
        rows.append((case, cap_prop, best_prop, gain))
        emit(f"hetero_F11/data_centric/{case}", plan_t * 1e6,
             f"planned_prop={plan[0] / total:.2f};capacity_prop={cap_prop:.2f};"
             f"sweep_min_at={best_prop:.2f};gain_vs_uniform={gain:.1f}%")
        # model-centric split of a hidden dim (Eq. 2, MXU-quantised)
        h = plan_model_centric(profiles, 1536, quantum=128)
        mt = step_latency_model(profiles, h, 1536)
        ut = step_latency_model(profiles, [768, 768], 1536)
        emit(f"hetero_F11/model_centric/{case}", mt * 1e6,
             f"h_split={h};gain_vs_uniform={(ut - mt) / ut * 100:.1f}%")
        # the paper's checks: minimum coincides with capacity proportion,
        # and skewed cases show double-digit data-centric gains
        assert abs(best_prop - cap_prop) <= 0.1, case
        if abs(t0 - t1) > 1:
            assert gain > 10, case
    return rows


if __name__ == "__main__":
    run()
