"""Paper Table 3 / Figure 11 — heterogeneous-aware allocation.

Two tiers (results land in ``BENCH_hetero.json``, schema in README):

1. **Analytical** (the paper's experiment logic, exactly): measure
   per-device capacity with the proxy task (here: calibrated latency
   profiles for the paper's three power-limit cases), sweep the division
   proportion, and verify the latency minimum sits at the capacity
   proportion (Eq. 1/2), with the paper's reported % gains over uniform.
2. **Executed** (DESIGN.md §6): actually RUN uniform vs proportional
   splits on a simulated-skew mesh — per-device programs with shapes cut
   from the plan (``parallel.hetero_exec``), measured wall times scaled by
   the skew, step latency = the barrier max. Asserts the proportional
   split's measured step latency beats uniform under 2x device skew.

On real heterogeneous hardware the same code path measures t_i by timing
the proxy matmul loop per device (``measure_capacity``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.hetero import (
    DeviceProfile,
    make_hetero_plan,
    plan_data_centric,
    plan_model_centric,
    proportional_split,
    step_latency_model,
    uniform_counterpart,
)
from repro.parallel.autotune import (
    Topology,
    dispatch_inter_bytes,
    moe_coll_bytes,
)
from repro.parallel.hetero_exec import HeteroExecutor

# Paper Table 3: (P0, t0, P1, t1) per case.
PAPER_CASES = {
    "case1_100W_300W": (4.58, 3.06),   # R = (0.40, 0.60)
    "case2_300W_300W": (3.20, 3.18),   # R = (0.50, 0.50)
    "case3_300W_100W": (3.28, 9.42),   # R = (0.74, 0.26)
}


def measure_capacity(size: int = 512, times: int = 16) -> float:
    """The paper's Appendix-B proxy task (scaled)."""
    key = jax.random.PRNGKey(0)
    m1 = jax.random.normal(key, (size, size))
    m2 = jax.random.normal(key, (size, size))
    f = jax.jit(lambda a, b: a @ b)
    jax.block_until_ready(f(m1, m2))
    t0 = time.perf_counter()
    for _ in range(times):
        m1 = f(m1, m2) / size
    jax.block_until_ready(m1)
    return time.perf_counter() - t0


def run(quick: bool = True):
    rows = []
    emit("hetero_T3/proxy_task_local", measure_capacity() * 1e6,
         "paper Appendix-B proxy on this host")
    for case, (t0, t1) in PAPER_CASES.items():
        profiles = [DeviceProfile("D0", t0), DeviceProfile("D1", t1)]
        total = 120
        # sweep division proportions (Fig. 11 x-axis)
        sweep = []
        for share0 in range(10, total - 9, 10):
            shares = [share0, total - share0]
            sweep.append(
                (share0 / total, step_latency_model(profiles, shares, total))
            )
        best_prop, best_t = min(sweep, key=lambda x: x[1])
        plan = plan_data_centric(profiles, total)
        plan_t = step_latency_model(profiles, plan, total)
        uni_t = step_latency_model(profiles, [total // 2, total // 2], total)
        gain = (uni_t - plan_t) / uni_t * 100
        cap_prop = (1 / t0) / (1 / t0 + 1 / t1)
        rows.append((case, cap_prop, best_prop, gain))
        emit(f"hetero_F11/data_centric/{case}", plan_t * 1e6,
             f"planned_prop={plan[0] / total:.2f};capacity_prop={cap_prop:.2f};"
             f"sweep_min_at={best_prop:.2f};gain_vs_uniform={gain:.1f}%")
        # model-centric split of a hidden dim (Eq. 2, MXU-quantised)
        h = plan_model_centric(profiles, 1536, quantum=128)
        mt = step_latency_model(profiles, h, 1536)
        ut = step_latency_model(profiles, [768, 768], 1536)
        emit(f"hetero_F11/model_centric/{case}", mt * 1e6,
             f"h_split={h};gain_vs_uniform={(ut - mt) / ut * 100:.1f}%")
        # the paper's checks: minimum coincides with capacity proportion,
        # and skewed cases show double-digit data-centric gains
        assert abs(best_prop - cap_prop) <= 0.1, case
        if abs(t0 - t1) > 1:
            assert gain > 10, case
    run_executed(quick=quick)
    run_topology(quick=quick)
    return rows


def run_executed(quick: bool = True) -> None:
    """Tier 2: execute uniform vs proportional splits for real (2x skew).

    Per-device programs (esffn/esmm grids sized from each device's B_i/h_i)
    run on this host; measured wall times x the skew factors give the
    synchronous step latency (the barrier max). Emits one row per
    (dispatch, split) plus the speedup, and asserts the Fig. 11 result on
    MEASURED numbers: proportional <= uniform under 2x skew."""
    lat = (1.0, 2.0)  # simulated 2x device skew
    rounds = 5 if quick else 10
    # Shapes where the split actually carries the runtime: many tokens for
    # the Eq. 1 token split, a wide FFN for the Eq. 2 hidden split (the
    # per-device routing is replicated there and does not shrink with h_i).
    shapes = {
        "data_centric": dict(d=64, f=512, n_tok=2048 if quick else 8192,
                             hq=128),
        "model_centric": dict(d=64, f=2048, n_tok=512 if quick else 2048,
                              hq=256),
    }
    # Margins absorb shared-host load noise: the data-centric gap is wide
    # (>1.2x in every measurement); model-centric splits only the FFN term
    # (routing is replicated per device), so its gap is thinner.
    for mode, margin in (("data_centric", 1.05), ("model_centric", 1.15)):
        d, f, n_tok, hq = (shapes[mode][key] for key in
                           ("d", "f", "n_tok", "hq"))
        e, k = 8, 2
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        params = {"router": jax.random.normal(ks[0], (d, e)) * 0.1,
                  "w_gate": jax.random.normal(ks[1], (e, d, f)) * 0.1,
                  "w_up": jax.random.normal(ks[2], (e, d, f)) * 0.1,
                  "w_down": jax.random.normal(ks[3], (e, f, d)) * 0.1}
        x = jax.random.normal(ks[4], (n_tok, d), jnp.float32)
        prop = make_hetero_plan(lat, global_batch=n_tok, hidden_size=f,
                                token_quantum=8, hidden_quantum=hq)
        uni = uniform_counterpart(prop)
        execs = {
            name: HeteroExecutor(params, num_experts=e, top_k=k, act="silu",
                                 glu=True, plan=plan, mode=mode, blk=128)
            for name, plan in (("uniform", uni), ("proportional", prop))
        }
        # Interleave the A/B rounds (like common.time_pair) so machine-load
        # drift hits both splits equally, and reduce each device's samples
        # with MIN before the barrier max: load spikes on a shared host are
        # one-sided (they only ever add time), so the per-device minimum is
        # the faithful unloaded estimate the skew model should scale.
        for ex in execs.values():  # compile/warm each program exactly once
            jax.block_until_ready(ex(x))
        samples = {name: [] for name in execs}
        for _ in range(rounds):
            for name, ex in execs.items():
                samples[name].append(
                    ex.timed_step(x, rounds=1, warmup=False).device_times_s)
        steps, dev_best = {}, {}
        for name, ex in execs.items():
            best = np.asarray(samples[name]).min(axis=0)
            dev_best[name] = best
            steps[name] = float(max(best * np.asarray(ex.skews)))
        for name, plan in (("uniform", uni), ("proportional", prop)):
            shares = (plan.token_counts if mode == "data_centric"
                      else plan.hidden_splits)
            emit(f"hetero_exec/{mode}/{name}", steps[name] * 1e6,
                 f"shares={list(shares)};skew=2x;dev_ms="
                 f"{[round(float(t) * 1e3, 2) for t in dev_best[name]]}")
        speedup = steps["uniform"] / steps["proportional"]
        emit(f"hetero_exec/{mode}/speedup", 0.0,
             f"proportional_vs_uniform={speedup:.2f}x")
        assert steps["proportional"] <= steps["uniform"] * margin, (
            mode, steps)


def run_topology(quick: bool = True) -> None:
    """Two-level fabric rows (DESIGN.md §10): step latency of one MoE layer
    under the flat vs hierarchical collective schedule on a 16-device
    2-nodes-per-4 fabric.

    The compute term is MEASURED on this host (one device's expert-FFN
    shard); the communication term prices each schedule's per-device byte
    volumes (``moe_coll_bytes`` + the top-k dispatch crossings of
    ``dispatch_inter_bytes``) at the topology's per-level bandwidths — the
    same model ``layer_latency`` uses, so the pinned ``hier < flat`` row
    (Makefile ``bench-check --lt``) tracks exactly what the runtime chooser
    believes. Numerical parity of the two schedules is pinned separately in
    tests/test_hier_dispatch.py on a real fake-device mesh."""
    tokens, d, f, e, k = (8192 if quick else 65536), 1024, 4096, 16, 2
    n_dev = 16
    topo = Topology(intra_bw=50e9, inter_bw=12.5e9, node_size=4)

    # measured per-device compute: this device's shard of the expert FFN
    # (tokens/n_dev rows through a gate+down pair at the layer's shapes)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    xs = jax.random.normal(ks[0], (tokens // n_dev, d), jnp.float32)
    w1 = jax.random.normal(ks[1], (d, f), jnp.float32) * 0.1
    w2 = jax.random.normal(ks[2], (f, d), jnp.float32) * 0.1
    comp_us = time_fn(jax.jit(lambda a: jax.nn.silu(a @ w1) @ w2), xs,
                      iters=3 if quick else 7)

    steps = {}
    for name, hier in (("flat", False), ("hier", True)):
        comm_s, parts = 0.0, []
        for mode in ("model_centric", "data_centric"):
            intra, inter = moe_coll_bytes(mode, tokens, d, f, e, k,
                                          n_dev=n_dev, topology=topo,
                                          hierarchical=hier)
            comm_s += intra / topo.intra_bw + inter / topo.inter_bw
            parts.append(f"{mode}:intra={intra / 1e6:.1f}MB,"
                         f"inter={inter / 1e6:.1f}MB")
        disp = dispatch_inter_bytes(tokens, d, k, n_dev=n_dev,
                                    node_size=topo.node_size,
                                    hierarchical=hier)
        comm_s += disp / topo.inter_bw
        steps[name] = comp_us + comm_s * 1e6
        emit(f"hetero/topology/{name}", steps[name],
             f"comp_us={comp_us:.1f};dispatch_inter={disp / 1e6:.1f}MB;"
             + ";".join(parts))
    # node-local combine + per-node weight staging strictly cut cross-node
    # bytes whenever the group spans >1 node — the schedule must pay off
    assert steps["hier"] < steps["flat"], steps


if __name__ == "__main__":
    run()
