"""Serving benchmark — paged vs dense continuous batching (DESIGN.md §7).

A mixed-length workload (short chat turns + long-context summarization
prompts in ONE batch — the shape dense slot caches are worst at) runs
through both drivers on the same tiny model and weights:

  * ``BatchedServer``: dense ``(num_slots, max_seq)`` KV rectangle
    allocated up front; every prompt token costs a full-batch macro-step.
  * ``PagedServer``: shared page pool, bulk-granted prompt pages +
    on-demand decode pages, chunked batch-1 prefill interleaved with
    decode macro-steps.

Emitted to ``BENCH_serve.json`` (per-suite routing in ``benchmarks/run.py``,
schema in README): measured tokens/s for each driver, HBM-resident KV-cache
bytes (dense rectangle vs peak live pages — the paper's memory claim on the
inference side), and the roofline pricing from
``parallel.autotune.decode_attn_bytes`` for the same workload.

Asserts (CI-enforced): paged peak cache bytes < dense cache bytes, and
paged tokens/s suffers no regression against dense.

The speculative suite (ISSUE 9, DESIGN.md §11) reruns the paged driver
with an n-gram ``SpecDecoder`` attached on a repetitive decode-heavy
workload and emits ``serve/spec/{on,off}/tokens_per_s`` (decode-phase
only — prefill excluded on both sides) plus the measured acceptance
rate; CI pins spec-on strictly faster and token-identical to spec-off.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro import configs as cfglib
from repro.common import cdiv, tree_bytes
from repro.launch import serve
from repro.models import lm
from repro.parallel import autotune
from repro.parallel.sharding import ParallelConfig, split_tree

NUM_SLOTS = 4
PAGE = 8
SPEC_K = 7   # draft depth for the speculative suite (cycle-heavy workload)


def _workload(cfg, quick: bool):
    """Mixed lengths: mostly short chat prompts, a few long-context ones."""
    rng = np.random.default_rng(0)
    n_chat, n_long = (8, 2) if quick else (24, 6)
    reqs = []
    rid = 0
    for _ in range(n_chat):
        plen = int(rng.integers(3, 10))
        reqs.append(serve.Request(
            rid=rid, prompt=rng.integers(
                0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new=8))
        rid += 1
    for _ in range(n_long):
        reqs.append(serve.Request(
            rid=rid, prompt=rng.integers(
                0, cfg.vocab_size, size=56).astype(np.int32),
            max_new=8))
        rid += 1
    rng.shuffle(reqs)
    return reqs


def _dense_kv_bytes(cache) -> int:
    return tree_bytes(cache["layers"])


def _timed_run(server, reqs):
    for r in reqs:
        server.submit(dataclasses.replace(r, out=[]))
    t0 = time.perf_counter()
    done = server.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    assert len(done) == len(reqs), "server dropped requests"
    return toks / dt, done


def run(quick: bool = True):
    # qwen3: global attention + MoE — the dense (slots, max_seq) rectangle
    # is real HBM (an all-SWA stack like mixtral's rolls its dense buffer
    # at window size; there the paged win comes from window page
    # reclamation instead, asserted in tests/test_serve_parity.py).
    cfg = dataclasses.replace(
        cfglib.get_smoke_config("qwen3-moe-30b-a3b"), dtype="float32")
    pcfg = ParallelConfig(blk=8)
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _workload(cfg, quick)
    max_seq = 64  # covers the longest request (56 + 8)
    maxp = cdiv(max_seq, PAGE)

    dense_srv = serve.BatchedServer(
        cfg, pcfg, None, num_slots=NUM_SLOTS, max_seq=max_seq,
        params=params)
    paged_srv = serve.PagedServer(
        cfg, pcfg, None, num_slots=NUM_SLOTS, page_size=PAGE,
        num_pages=1 + NUM_SLOTS * maxp, max_pages_per_slot=maxp,
        params=params, prefill_chunk=16)

    # warm each server's compiled steps off the clock (the servers are
    # reusable: slots reset at admission, the pool drains between runs),
    # then measure interleaved rounds and keep each driver's best — the
    # same machine-load-drift defence as common.time_pair, so a transient
    # spike on a shared CI host can't fail the throughput assert
    _timed_run(dense_srv, reqs)
    _timed_run(paged_srv, reqs)
    paged_srv.pool.reset_peak()
    dense_tps, paged_tps = 0.0, 0.0
    for _ in range(3):
        tps, dense_done = _timed_run(dense_srv, reqs)
        dense_tps = max(dense_tps, tps)
        tps, paged_done = _timed_run(paged_srv, reqs)
        paged_tps = max(paged_tps, tps)

    # the two drivers must agree token-for-token before we compare speed
    d_out = {r.rid: r.out for r in dense_done}
    p_out = {r.rid: r.out for r in paged_done}
    assert d_out == p_out, "paged and dense servers disagree on tokens"

    dense_bytes = _dense_kv_bytes(dense_srv.cache)
    pstats = paged_srv.stats()
    paged_bytes = pstats["peak_in_use_bytes"]

    emit("serve/dense/tokens_per_s", 1e6 / max(dense_tps, 1e-9),
         f"tok/s={dense_tps:.1f} slots={NUM_SLOTS} max_seq={max_seq}")
    emit("serve/paged/tokens_per_s", 1e6 / max(paged_tps, 1e-9),
         f"tok/s={paged_tps:.1f} page={PAGE} "
         f"peak_pages={pstats['peak_in_use_pages']} "
         f"speedup={paged_tps / dense_tps:.2f}x")
    emit("serve/dense/kv_cache_bytes", float(dense_bytes),
         f"bytes={dense_bytes} (up-front {NUM_SLOTS}x{max_seq} rectangle)")
    emit("serve/paged/kv_cache_bytes", float(paged_bytes),
         f"bytes={paged_bytes} peak live pages "
         f"({100 * paged_bytes / dense_bytes:.0f}% of dense)")

    # roofline pricing for the same mix (autotune cost entry)
    lens = [len(r.prompt) + r.max_new - 1 for r in reqs[:NUM_SLOTS]]
    for kind, kw in (("dense", {}), ("paged", {"lengths": lens,
                                               "page": PAGE})):
        bts = autotune.decode_attn_bytes(
            kind, num_slots=NUM_SLOTS, max_seq=max_seq,
            hq=cfg.num_heads, hkv=cfg.num_kv_heads, hd=cfg.hd,
            itemsize=4, **kw)
        emit(f"serve/{kind}/roofline_attn_bytes", float(bts),
             "decode-attn HBM bytes per macro-step (cost model)")

    # CI-enforced acceptance: less resident cache, no throughput regression
    assert paged_bytes < dense_bytes, (
        f"paged peak {paged_bytes} >= dense {dense_bytes}")
    assert paged_tps >= 0.9 * dense_tps, (
        f"paged {paged_tps:.1f} tok/s regressed vs dense {dense_tps:.1f}")

    _run_prefix(cfg, pcfg, params, quick)
    _run_spec(pcfg, quick)


def _dup_workload(cfg, quick: bool):
    """High-duplicate chat workload (ISSUE 6): every request opens with
    the same 32-token system prompt and appends a short unique user turn —
    the shape the CoW radix index exists for."""
    rng = np.random.default_rng(1)
    shared = rng.integers(0, cfg.vocab_size, size=32).astype(np.int32)
    reqs = []
    for rid in range(8 if quick else 20):
        tail = rng.integers(
            0, cfg.vocab_size, size=int(rng.integers(2, 6))).astype(np.int32)
        reqs.append(serve.Request(
            rid=rid, prompt=np.concatenate([shared, tail]), max_new=8))
    return reqs


def _run_prefix(cfg, pcfg, params, quick: bool):
    """Prefix-cached vs uncached paged serving on the duplicate workload:
    emits mean time-to-first-token for both (the ``validate_bench --lt``
    pin), the token hit-rate, and cached tokens/s."""
    reqs = _dup_workload(cfg, quick)
    max_seq = 64
    maxp = cdiv(max_seq, PAGE)

    def mk(prefix_cache):
        return serve.PagedServer(
            cfg, pcfg, None, num_slots=NUM_SLOTS, page_size=PAGE,
            num_pages=1 + NUM_SLOTS * maxp, max_pages_per_slot=maxp,
            params=params, prefill_chunk=16, prefix_cache=prefix_cache)

    srv_on, srv_off = mk(True), mk(False)
    _timed_run(srv_on, reqs)      # warm compile + populate the index
    _timed_run(srv_off, reqs)
    ttft_on, ttft_off = float("inf"), float("inf")
    tps_on = 0.0
    for _ in range(3):
        tps, done_on = _timed_run(srv_on, reqs)
        tps_on = max(tps_on, tps)
        ttft_on = min(ttft_on, float(np.mean(list(srv_on.ttft_s.values()))))
        _, done_off = _timed_run(srv_off, reqs)
        ttft_off = min(ttft_off,
                       float(np.mean(list(srv_off.ttft_s.values()))))
    assert {r.rid: r.out for r in done_on} == \
           {r.rid: r.out for r in done_off}, "prefix cache changed tokens"

    pf = srv_on.stats()["prefix"]
    hit_rate = pf["hit_tokens"] / max(pf["lookup_tokens"], 1)
    emit("serve/prefix/ttft/cached", ttft_on * 1e6,
         f"mean TTFT {ttft_on * 1e3:.1f}ms over {len(reqs)} requests "
         f"(32-token shared prefix, page={PAGE})")
    emit("serve/prefix/ttft/uncached", ttft_off * 1e6,
         f"mean TTFT {ttft_off * 1e3:.1f}ms — identical workload, "
         f"prefix cache off")
    emit("serve/prefix/hit_rate", hit_rate * 1e6,
         f"token hit-rate {hit_rate:.0%} ({pf['hit_tokens']} of "
         f"{pf['lookup_tokens']} prompt tokens served from cache; "
         f"{pf['evictions']} LRU evictions)")
    emit("serve/prefix/tokens_per_s", 1e6 / max(tps_on, 1e-9),
         f"tok/s={tps_on:.1f} with prefix cache on")

    # CI-enforced acceptance: cached prefill must actually cut TTFT, the
    # cache must actually hit, and draining it must leak nothing
    assert ttft_on < ttft_off, (
        f"prefix-cached TTFT {ttft_on * 1e3:.1f}ms not below uncached "
        f"{ttft_off * 1e3:.1f}ms")
    assert hit_rate > 0.3, f"hit rate {hit_rate:.0%} — cache never shared"
    srv_on.drop_prefix_cache()
    srv_on.pool.assert_consistent()
    assert srv_on.pool.free_pages == sum(srv_on.pool.shares)


def _spec_workload(cfg, quick: bool):
    """Repetitive decode-heavy workload (ISSUE 9): each prompt tiles a
    short motif, so the n-gram drafter's suffix matches keep hitting, and
    tiny random models settle into greedy cycles during decode — the
    high-acceptance regime speculative decoding exists for."""
    rng = np.random.default_rng(2)
    reqs = []
    for rid in range(6 if quick else 16):
        motif = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
        plen = int(rng.integers(16, 24))
        reqs.append(serve.Request(
            rid=rid, prompt=np.tile(motif, cdiv(plen, 4))[:plen],
            max_new=24))
    return reqs


def _run_spec(pcfg, quick: bool):
    """Speculative vs plain paged decoding (DESIGN.md §11): identical
    servers and weights, one with an n-gram ``SpecDecoder`` attached.
    Emits decode-phase tokens/s for both (prefill time excluded on both
    sides via ``decode_times_s``) and the measured acceptance rate; the
    ``validate_bench --lt`` pin holds spec-on strictly faster.

    Runs on the gemma smoke model rather than the qwen3-moe used above:
    its tiny random weights settle into short greedy cycles within a few
    decode steps, giving the n-gram drafter the high-acceptance stream
    this suite is meant to price (qwen3-moe's cycles are longer than the
    drafter's history, so acceptance there measures noise, not spec)."""
    from repro.launch import spec as spec_lib

    cfg = dataclasses.replace(
        cfglib.get_smoke_config("gemma-2b"), dtype="float32")
    params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
    reqs = _spec_workload(cfg, quick)
    max_seq = 64
    maxp = cdiv(max_seq, PAGE)

    # batch-1 serving: speculation prices LATENCY — at high batch the
    # plain macro-step already amortizes its launch over every slot, so
    # the canonical speculative win (and this pin) is the low-batch,
    # decode-bound regime
    def mk():
        return serve.PagedServer(
            cfg, pcfg, None, num_slots=1, page_size=PAGE,
            num_pages=1 + maxp, max_pages_per_slot=maxp,
            params=params, prefill_chunk=16)

    srv_on, srv_off = mk(), mk()
    dec = spec_lib.SpecDecoder(srv_on, spec_lib.NGramDrafter(3), k=SPEC_K)
    _timed_run(srv_on, reqs)      # warm both servers' compiled steps
    _timed_run(srv_off, reqs)

    def decode_tps(srv):
        srv.decode_times_s.clear()
        _, done = _timed_run(srv, reqs)
        toks = sum(len(r.out) - 1 for r in done)   # first token = prefill's
        return toks / max(sum(srv.decode_times_s), 1e-9), done

    tps_on, tps_off = 0.0, 0.0
    for _ in range(3):
        tps, done_on = decode_tps(srv_on)
        tps_on = max(tps_on, tps)
        tps, done_off = decode_tps(srv_off)
        tps_off = max(tps_off, tps)

    # exact-match verification is CI-checked here too: speculative output
    # must be token-identical, not merely same-distribution
    assert {r.rid: r.out for r in done_on} == \
           {r.rid: r.out for r in done_off}, "speculation changed tokens"

    rate = dec.acceptance_rate()
    sstats = dec.stats()
    emit("serve/spec/on/tokens_per_s", 1e6 / max(tps_on, 1e-9),
         f"decode tok/s={tps_on:.1f} ngram spec_k={SPEC_K} "
         f"speedup={tps_on / max(tps_off, 1e-9):.2f}x")
    emit("serve/spec/off/tokens_per_s", 1e6 / max(tps_off, 1e-9),
         f"decode tok/s={tps_off:.1f} — identical workload, no speculation")
    emit("serve/spec/acceptance", rate * 1e6,
         f"acceptance {rate:.0%} ({sstats['accepted_drafts']} of "
         f"{sstats['drafted']} drafted over {sstats['rounds']} rounds; "
         f"{sstats['rollback_tokens']} rows rolled back)")

    # CI-enforced acceptance: the drafter must actually hit on this
    # workload, and speculation must pay for its verify overhead
    assert rate > 0.4, f"acceptance {rate:.0%} — drafter never hits"
    assert tps_on > 1.5 * tps_off, (
        f"spec-on {tps_on:.1f} tok/s not >1.5x spec-off {tps_off:.1f}")
    srv_on.pool.assert_consistent()
    assert srv_on.pool.free_pages == sum(srv_on.pool.shares)
