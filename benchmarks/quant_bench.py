"""Quantization benchmark (ISSUE 5, DESIGN.md §8) — emitted to
``BENCH_quant.json`` via the per-suite routing in ``benchmarks/run.py``.

Four claims, each carried as a machine-readable row pair so
``scripts/validate_bench.py --lt`` can pin them in CI:

  * ``quant/esffn/bytes/{int8,bf16}`` — the fused-FFN cost model's
    ``bytes_accessed`` with int8 vs bf16 expert weights (the HBM bytes the
    megakernel actually moves; int8 must be strictly below).
  * ``quant/esffn/measured/{int8,f32}`` — measured blocked-path fused-FFN
    latency with true int8 payloads vs dense weights (informational on
    CPU, where the dequant is arithmetic, not bandwidth).
  * ``quant/crossover/tokens/{int8,bf16}`` — the data-/model-centric
    crossover token count under each weight width: int8 cheapens the
    data-centric weight movement, so its crossover must sit at or below
    bf16's (asserted).
  * ``quant/kv/admitted/{int8,fp}`` — concurrent requests a PagePool of
    EQUAL HBM bytes admits under int8 vs full-precision paged-KV pages
    (int8 must admit strictly more).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_pair
from repro import configs as cfglib
from repro.core.reindex import build_reindex
from repro.core.routing import route
from repro.kernels import ops
from repro.kernels.esffn import esffn_cost
from repro.models import lm
from repro.parallel import autotune
from repro.parallel.cache import PagePool
from repro.quant import core as qc


def _esffn_rows(quick: bool):
    n, d, f, e, k, blk = (256, 128, 256, 8, 2, 32) if quick else \
        (1024, 512, 1024, 8, 2, 128)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    router = jnp.asarray(rng.normal(size=(d, e)), jnp.float32)
    r = route(x, router, k)
    ri = build_reindex(r.expert_idx, r.gates, e, blk)
    wg, wu = (jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32)
              for _ in range(2))
    wd = jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32)
    (qg, sg), (qu, su), (qd, sd) = (qc.quantize_blockwise(w)
                                    for w in (wg, wu, wd))

    # cost-model bytes: what the Pallas megakernel declares it moves
    nm = ri.block_expert.shape[0]
    c16 = esffn_cost(ri.row_token.shape[0], d, f, nm, 2, glu=True,
                     weight_bits=16)
    c8 = esffn_cost(ri.row_token.shape[0], d, f, nm, 2, glu=True,
                    weight_bits=8)
    assert c8.bytes_accessed < c16.bytes_accessed
    emit("quant/esffn/bytes/int8", float(c8.bytes_accessed),
         f"cost-model HBM bytes, int8 weights (N={n} D={d} F={f} E={e})")
    emit("quant/esffn/bytes/bf16", float(c16.bytes_accessed),
         f"cost-model HBM bytes, bf16 weights "
         f"({100 * c8.bytes_accessed / c16.bytes_accessed:.0f}% -> int8)")

    def run_q():
        return ops.esffn_glu(x, ri.row_token, ri.row_gate, ri.block_expert,
                             ri.padded_counts, qg, qu, qd,
                             scales=(sg, su, sd), impl="blocked")

    def run_d():
        return ops.esffn_glu(x, ri.row_token, ri.row_gate, ri.block_expert,
                             ri.padded_counts, wg, wu, wd, impl="blocked")

    us_q, us_d, ratio = time_pair(run_q, run_d)
    emit("quant/esffn/measured/int8", us_q,
         f"blocked fused FFN, int8 payloads ({ratio:.2f}x of dense; CPU "
         "pays the dequant in arithmetic — the bytes win is the TPU story)")
    emit("quant/esffn/measured/f32", us_d, "blocked fused FFN, dense f32")


def _crossover_rows():
    d, f, e, k, n_dev = 1024, 4096, 8, 2, 16
    xo16 = autotune.crossover_tokens(d, f, e, k, n_dev=n_dev, weight_bits=16)
    xo8 = autotune.crossover_tokens(d, f, e, k, n_dev=n_dev, weight_bits=8)
    assert xo16 is not None and xo8 is not None and xo8 <= xo16, (xo8, xo16)
    emit("quant/crossover/tokens/int8", float(xo8),
         f"data-/model-centric crossover, int8 experts (d={d} f={f} e={e})")
    emit("quant/crossover/tokens/bf16", float(xo16),
         f"bf16 crossover — int8 pulls it {xo16 // max(xo8, 1)}x earlier")


def _kv_capacity_rows():
    cfg = dataclasses.replace(
        cfglib.get_smoke_config("qwen3-moe-30b-a3b"), dtype="float32")
    page = 8
    pb_fp = lm.paged_kv_page_bytes(cfg, page, None)
    pb_q = lm.paged_kv_page_bytes(cfg, page, "int8")
    budget = 64 * pb_fp  # a fixed HBM budget for the KV pool
    need = 6             # worst-case pages per representative request

    def capacity(page_bytes):
        pool = PagePool(1 + budget // page_bytes, page_bytes=page_bytes)
        n = 0
        while pool.try_reserve(need):
            n += 1
        return n

    cap_fp, cap_q = capacity(pb_fp), capacity(pb_q)
    assert cap_q > cap_fp, (cap_q, cap_fp)
    emit("quant/kv/admitted/fp", float(cap_fp),
         f"requests admitted at {budget} B KV budget, "
         f"{pb_fp} B/page full precision")
    emit("quant/kv/admitted/int8", float(cap_q),
         f"same budget, {pb_q} B/page int8+scales -> "
         f"{cap_q / max(cap_fp, 1):.1f}x admissions")


def run(quick: bool = True):
    _esffn_rows(quick)
    _crossover_rows()
    _kv_capacity_rows()
