"""Paper Figure 10 — data-centric vs model-centric latency crossover.

The paper's observation: model-centric wins at small workload, data-centric
wins at large. We reproduce it with the roofline latency model evaluated on
the ACTUAL per-mode costs of one MoE layer on the production mesh:

  model-centric: tokens all-gathered over TP + partial-output reduction;
                 weights stationary.
  data-centric : weights all-gathered over the mesh (cache re-fill per
                 layer); tokens stationary.

Cost model terms use the v5e constants from the dry-run (197 TF, 819 GB/s,
50 GB/s link); crossover position depends on the ratio of token bytes moved
(∝ batch) to weight bytes moved (constant) exactly as in the paper.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit

PEAK = 197e12
HBM = 819e9
LINK = 50e9


def layer_latency(mode: str, tokens: int, d: int, f: int, e: int, k: int,
                  n_dev: int = 16) -> float:
    """One MoE FFN layer (fwd), bf16, on an n_dev TP/DP group."""
    active_rows = tokens * k
    flops = 2 * active_rows * d * f * 2  # two MLPs
    w_bytes = e * 2 * d * f * 2          # full expert params, bf16
    tok_bytes = tokens * d * 2
    if mode == "model_centric":
        compute = flops / n_dev / PEAK           # rows x F/n per device
        mem = (w_bytes / n_dev + tok_bytes) / HBM
        coll = (tok_bytes + tok_bytes) / LINK    # AG tokens + RS outputs
    else:  # data_centric
        compute = flops / n_dev / PEAK           # tokens/n per device
        mem = (w_bytes + tok_bytes / n_dev) / HBM
        coll = w_bytes * (n_dev - 1) / n_dev / LINK  # AG weights
    return max(compute, mem, coll)


def run(quick: bool = True):
    d, f, e, k = 1024, 4096, 8, 2
    rows = []
    crossover = None
    # crossover where 2x token bytes ~ gathered weight bytes: ~E*f tokens
    batches = [2 ** i for i in range(4, 18)]
    prev = None
    for tokens in batches:
        t_m = layer_latency("model_centric", tokens, d, f, e, k)
        t_d = layer_latency("data_centric", tokens, d, f, e, k)
        rows.append((tokens, t_m, t_d))
        winner = "model" if t_m < t_d else "data"
        if prev and prev != winner:
            crossover = tokens
        prev = winner
        emit(f"centric_F10/tokens{tokens}", t_m * 1e6,
             f"model_us={t_m * 1e6:.1f};data_us={t_d * 1e6:.1f};winner={winner}")
    assert rows[0][1] < rows[0][2], "model-centric must win small workloads"
    assert rows[-1][2] < rows[-1][1], "data-centric must win large workloads"
    emit("centric_F10/crossover_tokens", 0.0, f"{crossover}")
    return rows


if __name__ == "__main__":
    run()
