"""Paper Figure 10 — data-centric vs model-centric latency crossover.

The paper's observation: model-centric wins at small workload, data-centric
wins at large. We reproduce it with the roofline latency model evaluated on
the ACTUAL per-mode costs of one MoE layer on the production mesh:

  model-centric: tokens all-gathered over TP + partial-output reduction;
                 weights stationary.
  data-centric : weights all-gathered over the mesh (cache re-fill per
                 layer); tokens stationary.

Cost model terms use the v5e constants from the dry-run (197 TF, 819 GB/s,
50 GB/s link); crossover position depends on the ratio of token bytes moved
(∝ batch) to weight bytes moved (constant) exactly as in the paper.

The cost model itself lives in ``repro.parallel.autotune`` (it is also the
runtime chooser behind ``ParallelConfig(mode="auto")``); this module keeps
the Fig. 10 sweep/emit harness on top of it so the offline roofline and the
runtime decision can never drift apart.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.parallel.autotune import crossover_tokens, layer_latency


def run(quick: bool = True):
    d, f, e, k = 1024, 4096, 8, 2
    rows = []
    crossover = None
    # crossover where 2x token bytes ~ gathered weight bytes: ~E*f tokens
    batches = [2 ** i for i in range(4, 18)]
    prev = None
    for tokens in batches:
        t_m = layer_latency("model_centric", tokens, d, f, e, k)
        t_d = layer_latency("data_centric", tokens, d, f, e, k)
        rows.append((tokens, t_m, t_d))
        winner = "model" if t_m < t_d else "data"
        if prev and prev != winner:
            crossover = tokens
        prev = winner
        emit(f"centric_F10/tokens{tokens}", t_m * 1e6,
             f"model_us={t_m * 1e6:.1f};data_us={t_d * 1e6:.1f};winner={winner}")
    assert rows[0][1] < rows[0][2], "model-centric must win small workloads"
    assert rows[-1][2] < rows[-1][1], "data-centric must win large workloads"
    assert crossover == crossover_tokens(d, f, e, k, n_dev=16), \
        "runtime chooser disagrees with the Fig. 10 sweep"
    emit("centric_F10/crossover_tokens", 0.0, f"{crossover}")
    return rows


if __name__ == "__main__":
    run()
