"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def compiled_stats(fn, *abstract_args) -> dict:
    """Compile (AOT) and return memory/cost stats without executing."""
    lowered = jax.jit(fn).lower(*abstract_args)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<=0.4.x returns [dict] per device
        ca = ca[0] if ca else {}
    return {
        "peak_bytes": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "arg_bytes": ma.argument_size_in_bytes,
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The run.py CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")
