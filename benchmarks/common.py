"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def time_pair(fn_a, fn_b, *args, rounds: int = 8) -> tuple[float, float, float]:
    """Interleaved A/B timing: (median_us_a, median_us_b, median a/b ratio).

    Alternating single calls makes the comparison robust to machine-load
    drift that would skew two back-to-back ``time_fn`` runs; the returned
    ratio is the median of the per-round a/b ratios (each round sees the
    same load), which is a steadier estimator than the ratio of medians.
    """
    for fn in (fn_a, fn_b, fn_a, fn_b):  # warm both (compile + caches)
        jax.block_until_ready(fn(*args))
    ta, tb = [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        tb.append(time.perf_counter() - t0)
    ratio = float(np.median(np.asarray(ta) / np.asarray(tb)))
    return float(np.median(ta) * 1e6), float(np.median(tb) * 1e6), ratio


def compiled_stats(fn, *abstract_args) -> dict:
    """Compile (AOT) and return memory/cost stats without executing."""
    lowered = jax.jit(fn).lower(*abstract_args)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<=0.4.x returns [dict] per device
        ca = ca[0] if ca else {}
    return {
        "peak_bytes": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "arg_bytes": ma.argument_size_in_bytes,
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }


#: Every emit() of the current process, in order — run.py serialises this to
#: BENCH_kernels.json so the per-PR perf trajectory is machine-readable.
RECORDS: list[dict] = []


def reset_records() -> None:
    RECORDS.clear()


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The run.py CSV contract: name,us_per_call,derived."""
    RECORDS.append(
        {"name": name, "us_per_call": float(us_per_call), "derived": derived}
    )
    print(f"{name},{us_per_call:.1f},{derived}")
