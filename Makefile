PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test bench docs-check ci

test:
	$(PY) -m pytest -x -q

bench:
	JAX_PLATFORMS=cpu $(PY) benchmarks/run.py --quick

# Every `DESIGN.md §N` citation in src/ must resolve to a `## §N` heading.
docs-check:
	@fail=0; \
	for n in $$(grep -rhoE 'DESIGN\.md §[0-9]+' src | grep -oE '[0-9]+' | sort -u); do \
		grep -qE "^## §$$n\b" DESIGN.md || { echo "dangling citation: DESIGN.md §$$n"; fail=1; }; \
	done; \
	[ $$fail -eq 0 ] && echo "docs-check: all DESIGN.md citations resolve" || exit 1

ci:
	bash scripts/ci.sh
