PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-tier1 test-multihost bench bench-check docs-check chaos obs-check ci

test:
	$(PY) -m pytest -x -q

# The two test tiers (tests/conftest.py markers): tier1 = fast in-process
# tests; multihost = subprocess tests driving an
# --xla_force_host_platform_device_count fake-device mesh (hierarchical
# dispatch parity, SPMD hetero execution, elastic CLI). `make test` runs
# both in one invocation.
test-tier1:
	$(PY) -m pytest -x -q -m "not multihost"

test-multihost:
	$(PY) -m pytest -x -q -m multihost

bench:
	JAX_PLATFORMS=cpu $(PY) benchmarks/run.py --quick

# Tier-2 chaos suite (DESIGN.md §9): seeded fault plans driven through the
# real train/serve drivers, asserting bit-exact recovery — checkpoint
# fallback past corruption, serving abort/retry/re-jit parity, elastic
# shrink on device dropout.
chaos:
	JAX_PLATFORMS=cpu REPRO_PALLAS_INTERPRET=1 $(PY) scripts/chaos.py

# Observability artifacts (DESIGN.md §12): drive train + paged-serve with
# every pillar on and validate the Prometheus text grammar, the Chrome
# trace schema + >=95% span coverage, the JSONL event log, and the
# per-expert router invariant sum(expert_tokens) == top_k * routed.
obs-check:
	JAX_PLATFORMS=cpu REPRO_PALLAS_INTERPRET=1 $(PY) scripts/obs_check.py

# Every `DESIGN.md §N` citation in src/ must resolve to a `## §N` heading,
# and every public API in parallel/ + runtime/ + quant/ + launch/ must
# carry a docstring.
docs-check:
	$(PY) scripts/docs_check.py

# BENCH_*.json must match the README-documented schema, the executed
# heterogeneous comparison rows must be present, and the serving
# paged-vs-dense comparison must carry both sides of every claim.
bench-check:
	$(PY) scripts/validate_bench.py BENCH_kernels.json BENCH_hetero.json \
		BENCH_serve.json BENCH_quant.json BENCH_obs.json \
		--require hetero_exec/data_centric/uniform \
		--require hetero_exec/data_centric/proportional \
		--require hetero_exec/model_centric/uniform \
		--require hetero_exec/model_centric/proportional \
		--require serve/paged/tokens_per_s \
		--require serve/dense/tokens_per_s \
		--require serve/prefix/hit_rate \
		--require serve/spec/on/tokens_per_s \
		--require serve/spec/acceptance \
		--require quant/esffn/bytes \
		--require hetero/topology/flat \
		--lt hetero/topology/hier:hetero/topology/flat \
		--lt serve/paged/kv_cache_bytes:serve/dense/kv_cache_bytes \
		--lt serve/prefix/ttft/cached:serve/prefix/ttft/uncached \
		--lt serve/spec/on/tokens_per_s:serve/spec/off/tokens_per_s \
		--lt quant/esffn/bytes/int8:quant/esffn/bytes/bf16 \
		--lt quant/crossover/tokens/int8:quant/crossover/tokens/bf16 \
		--lt quant/kv/admitted/fp:quant/kv/admitted/int8 \
		--require obs/overhead/step_ratio \
		--require obs/overhead/limit \
		--lt obs/overhead/step_ratio:obs/overhead/limit

ci:
	bash scripts/ci.sh
