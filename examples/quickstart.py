"""Quickstart: train a tiny Hexa-MoE LM on CPU and watch the loss drop,
then decode a few tokens — the whole public API in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.data.pipeline import DataConfig, TokenSource
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.optim import adamw
from repro.parallel.sharding import ParallelConfig, split_tree

cfg = ModelConfig(
    name="quickstart-moe", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=0, vocab_size=256, qk_norm=True,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=256),
)
pcfg = ParallelConfig(blk=16)
opt_cfg = adamw.OptimizerConfig(peak_lr=3e-3, warmup_steps=10,
                                decay_steps=100, master_fp32=False)
B, S = 8, 64

params, _ = split_tree(lm.init_params(jax.random.PRNGKey(0), cfg))
opt = adamw.init_opt_state(params, opt_cfg)
step = jax.jit(steps_lib.make_train_step(cfg, pcfg, None, opt_cfg,
                                         (B, S, cfg.d_model)))
data = TokenSource(DataConfig(seq_len=S, global_batch=B,
                              vocab_size=cfg.vocab_size))

first = None
for i in range(60):
    batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
    params, opt, m = step(params, opt, batch)
    first = first or float(m["loss"])
    if i % 10 == 0:
        print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
              f"aux {float(m['aux_loss']):.4f}")

final = float(m["loss"])
print(f"\nloss: {first:.3f} -> {final:.3f} "
      f"({'LEARNING' if final < first - 0.3 else 'no progress?!'})")

# decode 8 tokens greedily from the trained model
cache = lm.init_cache(cfg, 1, 32)
serve = jax.jit(steps_lib.make_serve_step(cfg, pcfg, None, (1, 1, cfg.d_model)))
tok = jnp.array([[5]], jnp.int32)
out = []
for _ in range(8):
    logits, cache = serve(params, {"tokens": tok}, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out.append(int(tok[0, 0]))
print("decoded:", out)
