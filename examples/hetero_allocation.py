"""Heterogeneous-aware allocation walkthrough (paper §4.4, Fig. 11;
DESIGN.md §6): measure capacities with the proxy task, plan Eq.1/Eq.2
splits, then RUN them — per-device programs execute the uneven shards for
real (``parallel.hetero_exec``) and the measured, skew-scaled step latency
shows the proportional split beating uniform. Ends with the runtime
straggler loop re-planning shares online.

  PYTHONPATH=src python examples/hetero_allocation.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.hetero import (  # noqa: E402
    DeviceProfile, make_hetero_plan, plan_data_centric, plan_model_centric,
    uniform_counterpart,
)
from repro.parallel.hetero_exec import HeteroExecutor  # noqa: E402
from repro.runtime.straggler import StragglerConfig, StragglerMonitor  # noqa: E402

profiles = [DeviceProfile("TITAN-RTX@100W", 4.58),
            DeviceProfile("2080Ti@300W", 3.06)]
lat = [p.proxy_latency_s for p in profiles]
total = 120

print("== Eq.1 data-centric batch split ==")
plan_b = plan_data_centric(profiles, total)
print(f"capacities {[f'{p.capacity:.3f}' for p in profiles]} "
      f"-> shares {plan_b}")

print("\n== Eq.2 model-centric hidden split (MXU-aligned) ==")
h = plan_model_centric(profiles, 4096, quantum=128)
print(f"hidden 4096 -> {h} (multiples of 128)")

print("\n== executed uneven splits (measured, not modelled) ==")
# Eq. 1 wants many tokens; Eq. 2 wants a wide FFN (per-device routing is
# replicated under the model split, so only the FFN term shrinks with h_i).
SHAPES = {"data_centric": (1024, 512, 64), "model_centric": (512, 2048, 256)}
E, K, D = 8, 2, 64
for mode in ("data_centric", "model_centric"):
    N, F, hq = SHAPES[mode]
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    params = {"router": jax.random.normal(ks[0], (D, E)) * 0.1,
              "w_gate": jax.random.normal(ks[1], (E, D, F)) * 0.1,
              "w_up": jax.random.normal(ks[2], (E, D, F)) * 0.1,
              "w_down": jax.random.normal(ks[3], (E, F, D)) * 0.1}
    x = jax.random.normal(ks[4], (N, D), jnp.float32)
    prop = make_hetero_plan(lat, global_batch=N, hidden_size=F,
                            token_quantum=8, hidden_quantum=hq)
    uni = uniform_counterpart(prop)
    print(f"-- {mode} --")
    for name, plan in (("uniform", uni), ("proportional", prop)):
        ex = HeteroExecutor(params, num_experts=E, top_k=K, act="silu",
                            glu=True, plan=plan, mode=mode, blk=128)
        st = ex.timed_step(x, rounds=6)
        shares = (plan.token_counts if mode == "data_centric"
                  else plan.hidden_splits)
        per_dev = ", ".join(
            f"{p.name}: {t * 1e3:.2f}ms (x{s:.2f} skew -> {t * s * 1e3:.2f}ms)"
            for p, t, s in zip(profiles, st.device_times_s, ex.skews))
        # the synchronous step ends when the slowest device finishes
        print(f"  {name:12s} shares={shares}  [{per_dev}]  "
              f"step={st.step_latency_s * 1e3:.2f}ms")

print("\n== runtime straggler loop ==")
mon = StragglerMonitor(4, 64, StragglerConfig(window=4,
                                              min_steps_between_replans=0))
rng = np.random.default_rng(0)
for step in range(10):
    times = [1.0 + 0.02 * rng.standard_normal() for _ in range(4)]
    if step >= 4:
        times[2] *= 2.2  # device 2 starts throttling
    new = mon.report(times)
    if new:
        print(f"step {step}: replanned shares -> {new}")
print(f"final shares: {mon.shares}")
