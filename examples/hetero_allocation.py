"""Heterogeneous-aware allocation walkthrough (paper §4.4, Fig. 11):
measure capacities with the proxy task, plan Eq.1/Eq.2 splits, sweep the
division and print the latency curve — the minimum lands on the planned
proportion. Also demonstrates the runtime straggler loop re-planning.

  PYTHONPATH=src python examples/hetero_allocation.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core.hetero import (  # noqa: E402
    DeviceProfile, plan_data_centric, plan_model_centric,
    step_latency_model,
)
from repro.runtime.straggler import StragglerConfig, StragglerMonitor  # noqa: E402

profiles = [DeviceProfile("TITAN-RTX@100W", 4.58),
            DeviceProfile("2080Ti@300W", 3.06)]
total = 120

print("== Eq.1 data-centric batch split ==")
plan = plan_data_centric(profiles, total)
print(f"capacities {[f'{p.capacity:.3f}' for p in profiles]} "
      f"-> shares {plan}")

print("\ndivision sweep (latency model):")
for share0 in range(20, 101, 10):
    t = step_latency_model(profiles, [share0, total - share0], total)
    marker = " <== planned" if abs(share0 - plan[0]) < 5 else ""
    print(f"  D0={share0:3d}/{total}  latency {t:.3f}s{marker}")

print("\n== Eq.2 model-centric hidden split (MXU-aligned) ==")
h = plan_model_centric(profiles, 4096, quantum=128)
print(f"hidden 4096 -> {h} (multiples of 128)")

print("\n== runtime straggler loop ==")
mon = StragglerMonitor(4, 64, StragglerConfig(window=4,
                                              min_steps_between_replans=0))
rng = np.random.default_rng(0)
for step in range(10):
    times = [1.0 + 0.02 * rng.standard_normal() for _ in range(4)]
    if step >= 4:
        times[2] *= 2.2  # device 2 starts throttling
    new = mon.report(times)
    if new:
        print(f"step {step}: replanned shares -> {new}")
print(f"final shares: {mon.shares}")
