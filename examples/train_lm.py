"""End-to-end training driver example: a ~100M-parameter Qwen3-style MoE LM
trained for a few hundred steps with the production driver (checkpointing,
fault tolerance, resume). CPU-scaled defaults; pass --steps/--batch to
change, --resume to continue a run.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig, MoEConfig  # noqa: E402
from repro.launch import train as train_mod            # noqa: E402
import repro.configs as cfglib                         # noqa: E402


# ~100M params: emb 32k x 384 (12.3M) + 8L x (attn 2.4M + 16e x 3 x 384 x 512
#   = 9.4M MoE) => ~107M total, ~32M active (top-4).
CONFIG_100M = ModelConfig(
    name="hexa-moe-100m", family="moe",
    num_layers=8, d_model=384, num_heads=8, num_kv_heads=4, head_dim=48,
    d_ff=0, vocab_size=32768, qk_norm=True, tie_embeddings=True,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff=512),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm_100m")
    args = ap.parse_args()

    # register the config under a name the driver can find
    import types
    mod = types.ModuleType("repro.configs.hexa_moe_100m")
    mod.CONFIG = CONFIG_100M
    mod.SMOKE_CONFIG = CONFIG_100M
    sys.modules["repro.configs.hexa_moe_100m"] = mod

    argv = [
        "--arch", "hexa_moe_100m",
        "--steps", str(args.steps),
        "--global-batch", str(args.batch),
        "--seq-len", str(args.seq_len),
        "--ckpt-dir", args.ckpt_dir,
        "--save-every", "50",
        "--lr", "1e-3",
        "--log-every", "10",
        "--metrics-out", "experiments/train_lm_100m_metrics.json",
    ]
    if args.resume:
        argv.append("--resume")
    metrics = train_mod.main(argv)
    if metrics:
        print(f"\nfirst loss {metrics[0]['loss']:.3f} -> "
              f"final loss {metrics[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
