"""Batched serving example: continuous-batching-lite server on a tiny
Mixtral-style model (MoE decode path with sliding-window KV cache).

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve  # noqa: E402

if __name__ == "__main__":
    serve.main([
        "--arch", "mixtral-8x7b", "--smoke",
        "--slots", "4", "--max-seq", "64",
        "--requests", "6", "--max-new", "12",
    ])
