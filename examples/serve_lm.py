"""Batched serving example: continuous-batching-lite server on a tiny
Mixtral-style model (MoE decode path with sliding-window KV cache), run
twice — uniform, then under a heterogeneous Eq. 1 slot plan (paper §4.4,
DESIGN.md §6) with measured (not modelled) decode-step latency reported by
the driver.

  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, "src")
# 4 fake CPU devices for the (2,2) heterogeneous mesh (set before jax loads)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")

from repro.launch import serve  # noqa: E402

if __name__ == "__main__":
    base = [
        "--arch", "mixtral-8x7b", "--smoke",
        "--slots", "4", "--max-seq", "64",
        "--requests", "6", "--max-new", "12",
    ]
    print("== uniform serving ==")
    serve.main(base)
    print("\n== heterogeneous serving (Eq. 1 slot shares over 2 data ranks,"
          " Eq. 2 hidden tiles over 2 TP ranks) ==")
    serve.main(base + [
        "--mesh", "2,2",
        "--hetero-latencies", "1.0,2.0",
        "--hetero-tp-latencies", "1.0,1.5",
    ])
